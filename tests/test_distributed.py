"""Distributed paths that need multiple (placeholder) devices run in a
subprocess so the 1-device main test session stays clean."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_dist_hck_matvec_and_cg():
    """shard_map distributed HCK == dense oracle of the composed kernel."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.kernels_fn import BaseKernel
from repro.launch import dist_hck

P_DEV, n_local, d, rank = 8, 64, 4, 8
ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (P_DEV * n_local, d))
local_fs = [dist_hck.build_local_factors(
    x[i*n_local:(i+1)*n_local], kernel=ker, rank=rank, local_levels=2,
    key=jax.random.fold_in(key, i)) for i in range(P_DEV)]
root_lms = jnp.stack([f.landmarks[0][0] for f in local_fs])
top = dist_hck.build_top_factors(root_lms, kernel=ker, key=jax.random.PRNGKey(7))
A = dist_hck.dist_to_dense(local_fs, top)
assert float(jnp.linalg.eigvalsh(A).min()) > 0
b = jax.random.normal(jax.random.PRNGKey(3), (P_DEV * n_local, 1))
mv = dist_hck.make_dist_matvec("dev")
mesh = jax.make_mesh((P_DEV,), ("dev",))
stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *local_fs)
def body(local_f, top, b_local):
    local_f = jax.tree.map(lambda a: a[0], local_f)
    return mv(local_f, top, b_local[0])[None]
from jax.experimental.shard_map import shard_map
sm = shard_map(body, mesh=mesh, in_specs=(P("dev"), P(), P("dev")),
               out_specs=P("dev"))
y = jax.jit(sm)(stacked, top, b.reshape(P_DEV, n_local, 1))
err = float(jnp.max(jnp.abs(y.reshape(-1, 1) - A @ b)))
assert err < 1e-3, err
def gmv(v):
    return jax.jit(sm)(stacked, top, v.reshape(P_DEV, n_local, 1)).reshape(-1)
xs = dist_hck.dist_solve(gmv, b[:, 0], ridge=0.5, iters=80)
xr = jnp.linalg.solve(A + 0.5*jnp.eye(A.shape[0]), b[:, 0])
assert float(jnp.max(jnp.abs(xs - xr))) < 1e-3
print("DIST_OK")
"""
    assert "DIST_OK" in _run(code)


@pytest.mark.slow
def test_dryrun_single_cell_compiles():
    """The multi-pod dry-run machinery itself: one decode cell on the
    2x16x16 mesh must lower + compile (compile-only, no cost probes)."""
    code = """
from repro.launch.dryrun import dryrun_cell
rec = dryrun_cell("granite-3-2b", "decode_32k", multi_pod=True,
                  skip_cost=True, verbose=False)
assert rec["ok"], rec.get("error")
assert rec["memory"]["argument_bytes"] > 0
print("DRYRUN_OK", rec["memory"]["argument_bytes"])
"""
    # dryrun module sets its own 512-device XLA_FLAGS at import
    out = _run(code, devices=512, timeout=560)
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_sharded_train_step_multidevice():
    """The real train step under an (2, 4) mesh on 8 host devices: params
    FSDP+TP sharded, batch DP sharded — executes (not just compiles)."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch, TrainConfig
from repro.configs.base import MeshConfig
from repro.models.transformer import init_params, param_pspecs
from repro.models.layers import axis_rules
from repro.training.train_loop import make_train_step
from repro.training import optimizer as opt
from repro.data.pipeline import TokenPipeline

cfg = get_arch("granite-3-2b").reduced()
mesh = jax.make_mesh((2, 4), ("data", "model"))
mcfg = MeshConfig(data=2, model=4, pods=1)
params = init_params(cfg, jax.random.PRNGKey(0))
pspecs = param_pspecs(cfg, mcfg)
param_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
params = jax.tree.map(jax.device_put, params, param_sh)
state = opt.init_opt_state(params)
tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=5)
pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=4)
batch = pipe.batch_at(0)
batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
step = jax.jit(make_train_step(cfg, tcfg))
with mesh:
    with axis_rules(("data",)):
        params, state, metrics = step(params, state, batch)
loss = float(metrics["loss"])
assert loss == loss and loss < 20  # finite
print("SHARDED_TRAIN_OK", loss)
"""
    assert "SHARDED_TRAIN_OK" in _run(code)


@pytest.mark.slow
def test_dist_cg_preconditioner_accelerates():
    """The local Algorithm-2 inverse as a CG preconditioner: fewer
    iterations to a given residual than plain CG (the distributed-KRR
    solver path in launch/dist_hck.py)."""
    code = """
import jax, jax.numpy as jnp
from repro.core.kernels_fn import BaseKernel
from repro.core import hmatrix
from repro.launch import dist_hck

P_DEV, n_local, rank = 4, 128, 16
ker = BaseKernel("gaussian", sigma=1.0, jitter=1e-5)
key = jax.random.PRNGKey(0)
x = jax.random.uniform(key, (P_DEV * n_local, 4))
local_fs = [dist_hck.build_local_factors(
    x[i*n_local:(i+1)*n_local], kernel=ker, rank=rank, local_levels=2,
    key=jax.random.fold_in(key, i)) for i in range(P_DEV)]
root_lms = jnp.stack([f.landmarks[0][0] for f in local_fs])
top = dist_hck.build_top_factors(root_lms, kernel=ker, key=jax.random.PRNGKey(7))
A = dist_hck.dist_to_dense(local_fs, top)
ridge = 0.05
b = jax.random.normal(jax.random.PRNGKey(3), (A.shape[0],))

def mv(v):
    return A @ v

# block-diagonal local preconditioner from the per-device Algorithm-2 inverse
invs = [hmatrix.invert(f, ridge) for f in local_fs]
def precond(r):
    parts = [hmatrix.apply_inverse(inv, r[i*n_local:(i+1)*n_local][:, None])[:, 0]
             for i, inv in enumerate(invs)]
    return jnp.concatenate(parts)

xref = jnp.linalg.solve(A + ridge * jnp.eye(A.shape[0]), b)
def err_after(iters, pc):
    xs = dist_hck.dist_solve(mv, b, ridge=ridge, iters=iters, precond=pc)
    return float(jnp.linalg.norm(xs - xref) / jnp.linalg.norm(xref))

e_plain = err_after(8, None)
e_pc = err_after(8, precond)
print("plain:", e_plain, "precond:", e_pc)
assert e_pc < e_plain, (e_pc, e_plain)
print("PRECOND_OK")
"""
    assert "PRECOND_OK" in _run(code, devices=4)


@pytest.mark.slow
def test_elastic_restart_different_device_count():
    """Fault-tolerance: a checkpoint written under a 4-device mesh restores
    and keeps training under an 8-device mesh (elastic re-shard: global
    shapes + device_put with the new shardings)."""
    import tempfile

    ckdir = tempfile.mkdtemp()
    save_code = f"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch, TrainConfig
from repro.configs.base import MeshConfig
from repro.models.transformer import init_params, param_pspecs
from repro.models.layers import axis_rules
from repro.training.train_loop import make_train_step
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.data.pipeline import TokenPipeline

cfg = get_arch("granite-3-2b").reduced()
mesh = jax.make_mesh((2, 2), ("data", "model"))
mcfg = MeshConfig(data=2, model=2)
params = init_params(cfg, jax.random.PRNGKey(0))
pspecs = param_pspecs(cfg, mcfg)
sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                  is_leaf=lambda x: isinstance(x, P))
params = jax.tree.map(jax.device_put, params, sh)
state = opt.init_opt_state(params)
tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10)
pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=2)
step = jax.jit(make_train_step(cfg, tcfg))
with mesh:
    with axis_rules(("data",)):
        params, state, m = step(params, state, pipe.batch_at(0))
CheckpointManager("{ckdir}").save(0, {{"params": params, "opt": state}})
print("SAVED", float(m["loss"]))
"""
    out = _run(save_code, devices=4)
    assert "SAVED" in out

    restore_code = f"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch, TrainConfig
from repro.configs.base import MeshConfig
from repro.models.transformer import init_params, param_pspecs
from repro.models.layers import axis_rules
from repro.training.train_loop import make_train_step
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager, reshard_restore
from repro.data.pipeline import TokenPipeline

assert jax.device_count() == 8
cfg = get_arch("granite-3-2b").reduced()
mesh = jax.make_mesh((2, 4), ("data", "model"))   # DIFFERENT topology
mcfg = MeshConfig(data=2, model=4)
template_params = init_params(cfg, jax.random.PRNGKey(0))
template_opt = opt.init_opt_state(template_params)
step_got, state = CheckpointManager("{ckdir}").restore(
    {{"params": template_params, "opt": template_opt}})
assert step_got == 0
pspecs = param_pspecs(cfg, mcfg)
sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                  is_leaf=lambda x: isinstance(x, P))
params = reshard_restore(state["params"], sh)
opt_state = jax.tree.map(jnp.asarray, state["opt"])
tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10)
pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=2)
step = jax.jit(make_train_step(cfg, tcfg))
with mesh:
    with axis_rules(("data",)):
        params, opt_state, m = step(params, opt_state, pipe.batch_at(1))
loss = float(m["loss"])
assert loss == loss and loss < 20
print("ELASTIC_OK", loss)
"""
    out = _run(restore_code, devices=8)
    assert "ELASTIC_OK" in out


# ---------------------------------------------------------------------------
# In-process multi-device tests: the CI ``test-multidevice`` lane runs this
# file under XLA_FLAGS=--xla_force_host_platform_device_count=8, where
# these execute directly (no subprocess); a 1-device session skips them.
# ---------------------------------------------------------------------------

import jax

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@needs_mesh
def test_axis_dot_cg_mesh_invariance(f64):
    """pcg inside an explicit shard_map body with the psum inner product
    (axis_dot) == the single-host solve: SAME iteration count, same x.
    check_rep=False is required (no replication rule for while_loop)."""
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import kernel_mesh
    from repro.solvers.cg import axis_dot, pcg

    n = 512
    diag = jnp.linspace(1.0, 5.0, n, dtype=jnp.float64)
    b = jax.random.normal(jax.random.PRNGKey(0), (n, 2), dtype=jnp.float64)

    r_host = pcg(lambda v: diag[:, None] * v, b, ridge=0.1, tol=1e-10,
                 maxiter=200)

    mesh = kernel_mesh(8)

    def body(d_loc, b_loc):
        r = pcg(lambda v: d_loc[:, None] * v, b_loc, ridge=0.1, tol=1e-10,
                maxiter=200, dot=axis_dot("dev"))
        return r.x, r.iterations

    x_mesh, it_mesh = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("dev"), P("dev")),
        out_specs=(P("dev"), P()), check_rep=False))(diag, b)
    assert int(it_mesh) == int(r_host.iterations)
    assert bool(r_host.converged)
    assert float(jnp.max(jnp.abs(x_mesh - r_host.x))) < 1e-10


@needs_mesh
def test_slq_logdet_shard_map_contract(f64):
    """slq_logdet under shard_map (local n, global n_total, psum
    all_reduce, per-device fold_in of the probe key) recovers the exact
    logdet of a diagonal operator with few distinct eigenvalues (the
    quadrature is exact once iters exceeds the spectrum size)."""
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import kernel_mesh
    from repro.solvers import slq

    n = 512
    vals = jnp.asarray([1.0, 2.0, 4.0, 8.0], dtype=jnp.float64)
    diag = jnp.tile(vals, n // 4)
    exact = float(jnp.sum(jnp.log(diag)))
    mesh = kernel_mesh(8)

    def body(d_loc):
        key = jax.random.fold_in(jax.random.PRNGKey(3),
                                 jax.lax.axis_index("dev"))
        return slq.slq_logdet(
            lambda v: d_loc * v, d_loc.shape[0], probes=4, iters=8,
            key=key, dtype=jnp.float64,
            all_reduce=lambda s: jax.lax.psum(s, "dev"), n_total=n)

    ld = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("dev"),),
                           out_specs=P(), check_rep=False))(diag)
    assert abs(float(ld) - exact) < 1e-8 * abs(exact)


@needs_mesh
def test_sharded_operator_gspmd_solve(f64):
    """pcg + hmatrix.solve on subtree-sharded inputs (plain jit, GSPMD)
    match their single-host results — no hooks needed on this path."""
    import jax.numpy as jnp

    from repro.core import hmatrix
    from repro.core.hck import build_hck
    from repro.core.kernels_fn import BaseKernel
    from repro.launch.dist_hck import shard_by_subtree
    from repro.launch.mesh import kernel_mesh
    from repro.solvers.cg import pcg
    from repro.solvers.operators import HCKOp

    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-8)
    x = jax.random.normal(jax.random.PRNGKey(0), (1024, 4),
                          dtype=jnp.float64)
    f = build_hck(x, levels=3, rank=64, key=jax.random.PRNGKey(1),
                  kernel=ker)
    y = (jnp.sin(x[:, 0]) + 0.25 * jnp.cos(2.0 * x[:, 1]))[:, None]
    ys = y[f.tree.perm]
    mesh = kernel_mesh(8)

    op = HCKOp(f)
    r_host = pcg(op, ys, ridge=1e-2, tol=1e-8, maxiter=400)
    r_mesh = pcg(op.sharded(mesh), ys, ridge=1e-2, tol=1e-8, maxiter=400)
    assert bool(r_host.converged) and bool(r_mesh.converged)
    assert float(jnp.max(jnp.abs(r_mesh.x - r_host.x))) < 1e-6

    a_host = hmatrix.solve(f, ys, ridge=1e-2)
    a_mesh = hmatrix.solve(shard_by_subtree(f, mesh), ys, ridge=1e-2)
    assert float(jnp.max(jnp.abs(a_mesh - a_host))) < 1e-8


@needs_mesh
def test_mesh_predict_engine_matches_single_host(f64):
    """Device-routed serving == the single-host shape-bucketed engine,
    including an empty batch and a batch above max_bucket."""
    import jax.numpy as jnp

    from repro.core import hmatrix, oos
    from repro.core.hck import build_hck
    from repro.core.kernels_fn import BaseKernel
    from repro.launch.mesh import kernel_mesh
    from repro.serving.predict_service import PredictEngine

    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-8)
    x = jax.random.normal(jax.random.PRNGKey(0), (1024, 4),
                          dtype=jnp.float64)
    f = build_hck(x, levels=5, rank=16, key=jax.random.PRNGKey(1),
                  kernel=ker)
    y = (jnp.sin(x[:, 0]) + 0.25 * jnp.cos(2.0 * x[:, 1]))[:, None]
    alpha = hmatrix.solve(f, y[f.tree.perm], ridge=1e-2)
    plan = oos.prepare(f, alpha)
    eng = PredictEngine(f, plan, ker)
    mesh = kernel_mesh(8)
    m_eng = eng.on_mesh(mesh, min_bucket=16, max_bucket=128)

    assert m_eng.apply(jnp.zeros((0, 4), jnp.float64)).shape == (0, 1)
    for q in (1, 37, 300):          # 300 > max_bucket: micro-batches
        xq = jax.random.normal(jax.random.PRNGKey(q), (q, 4),
                               dtype=jnp.float64)
        z_host = eng.apply(xq)
        z_mesh = m_eng.apply(xq)
        assert z_mesh.shape == z_host.shape
        assert float(jnp.max(jnp.abs(z_mesh - z_host))) < 1e-10


