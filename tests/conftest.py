"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
only launch/dryrun.py (and subprocess-based tests) use placeholder devices.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.kernels_fn import BaseKernel
from repro.core.hck import build_hck


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Release compiled executables at module boundaries.

    The full suite compiles several hundred distinct programs in one
    process; past ~300 the XLA CPU client's accumulated executables can
    segfault LLVM codegen on the next large compile.  Dropping the
    compilation/tracing caches per module keeps the live-executable
    count bounded at the cost of a few re-traces for cross-module
    shapes.
    """
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def f64():
    """Enable float64 for oracle-grade comparisons (session-wide)."""
    jax.config.update("jax_enable_x64", True)
    yield
    # leave enabled: cheaper than flapping the flag between tests


@pytest.fixture(scope="session")
def small_problem(f64):
    """(x, kernel, factors) for a 256-point float64 HCK instance."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 5), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-8)
    f = build_hck(x, levels=3, rank=16, key=jax.random.PRNGKey(1), kernel=ker)
    return x, ker, f
