"""HCK hierarchical attention: structured path == dense reference of the
same approximation; convergence toward exact with rank; causality; decode
== train-time last row; exact backends agree with each other."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention_backends import (HCKAttnConfig, _normalize,
                                             build_hck_decode_state,
                                             chunked_attention,
                                             decode_attention,
                                             dense_attention, hck_attention,
                                             hck_attention_reference,
                                             hck_decode_attention)


@pytest.fixture(scope="module")
def qkv():
    key = jax.random.PRNGKey(0)
    B, H, Hkv, S, D = 2, 4, 2, 256, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    return q, k, v


def test_hck_matches_dense_reference(qkv):
    q, k, v = qkv
    cfg = HCKAttnConfig(leaf=32, rank=16, levels=3)
    got = hck_attention(q, k, v, cfg=cfg)
    want = hck_attention_reference(q, k, v, cfg=cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-5)


def test_hck_converges_with_rank(qkv):
    """Approximation error vs exact cosine attention decreases with rank."""
    q, k, v = qkv
    d = q.shape[-1]
    tau = min(d ** 0.5, 16.0)
    exact = dense_attention(_normalize(q) * tau * (d ** 0.5), _normalize(k),
                            v, causal=True)
    errs = []
    for r in (4, 16, 32):
        cfg = HCKAttnConfig(leaf=32, rank=r, levels=3)
        out = hck_attention(q, k, v, cfg=cfg)
        errs.append(float(jnp.mean(jnp.abs(out - exact))))
    assert errs[0] > errs[1] > errs[2]


def test_hck_causality(qkv):
    """Future tokens cannot influence the past: perturb the tail, early
    outputs must be bit-identical."""
    q, k, v = qkv
    cfg = HCKAttnConfig(leaf=32, rank=8, levels=3)
    out1 = hck_attention(q, k, v, cfg=cfg)
    k2 = k.at[:, :, -32:].add(10.0)
    v2 = v.at[:, :, -32:].add(10.0)
    q2 = q.at[:, :, -32:].add(10.0)
    out2 = hck_attention(q2, k2, v2, cfg=cfg)
    np.testing.assert_allclose(np.asarray(out1[:, :, :192]),
                               np.asarray(out2[:, :, :192]), rtol=1e-6,
                               atol=1e-6)


def test_hck_decode_matches_train_last_row(qkv):
    q, k, v = qkv
    cfg = HCKAttnConfig(leaf=32, rank=16, levels=3)
    train_out = hck_attention(q, k, v, cfg=cfg)
    state = build_hck_decode_state(k, v, cfg=cfg)
    dec = hck_decode_attention(q[:, :, -1:], state)
    np.testing.assert_allclose(np.asarray(dec[:, :, 0]),
                               np.asarray(train_out[:, :, -1]), rtol=2e-4,
                               atol=2e-5)


def test_chunked_matches_dense(qkv):
    q, k, v = qkv
    for window in (0, 64):
        got = chunked_attention(q, k, v, causal=True, window=window, block=64)
        want = dense_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=1e-5)


def test_decode_matches_dense_last_row(qkv):
    q, k, v = qkv
    want = dense_attention(q, k, v, causal=True)
    got = decode_attention(q[:, :, -1:], k, v, length=k.shape[2])
    np.testing.assert_allclose(np.asarray(got[:, :, 0]),
                               np.asarray(want[:, :, -1]), rtol=2e-4,
                               atol=1e-5)


def test_decode_length_masking(qkv):
    """Cache slots beyond `length` must not contribute."""
    q, k, v = qkv
    half = k.shape[2] // 2
    got_full_cache = decode_attention(
        q[:, :, half - 1:half],
        k.at[:, :, half:].set(99.0), v.at[:, :, half:].set(99.0),
        length=half)
    got_trunc = decode_attention(q[:, :, half - 1:half], k[:, :, :half],
                                 v[:, :, :half], length=half)
    np.testing.assert_allclose(np.asarray(got_full_cache),
                               np.asarray(got_trunc), rtol=1e-5, atol=1e-6)


def test_for_seq_clamps_levels():
    cfg = HCKAttnConfig(leaf=1024, rank=64, levels=5)
    assert cfg.for_seq(4096).levels <= 4
    assert cfg.for_seq(524288).levels == 5
    assert cfg.for_seq(256).levels == 0
