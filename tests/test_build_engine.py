"""Batched Algorithm-2 build engine: parity, streaming, and stage tests.

Oracles: ``build_hck_reference`` is the per-node host-loop transcription of
the paper's Algorithm 2 (same key tree as the engine, so factors must
agree to factorization round-off); the ``build_stage`` jnp refs are the
stage-level oracles for the fused Pallas kernels.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import krr
from repro.core.hck import (build_hck, build_hck_reference,
                            build_hck_streaming, to_dense)
from repro.core.kernels_fn import BaseKernel
from repro.data.pipeline import ArraySource, pad_source, stream_partition
from repro.kernels.registry import SolveConfig, get_impl


def _assert_factors_close(fa, fb, atol, x_exact=True):
    if x_exact:
        np.testing.assert_array_equal(np.asarray(fa.x_sorted),
                                      np.asarray(fb.x_sorted))
        np.testing.assert_array_equal(np.asarray(fa.tree.perm),
                                      np.asarray(fb.tree.perm))
    np.testing.assert_allclose(np.asarray(fa.adiag), np.asarray(fb.adiag),
                               atol=atol)
    np.testing.assert_allclose(np.asarray(fa.u), np.asarray(fb.u), atol=atol)
    for name in ("sigma", "sigma_cho", "w"):
        for a, b in zip(getattr(fa, name), getattr(fb, name)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=atol)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("name", ["gaussian", "laplace", "imq"])
def test_engine_matches_reference(f64, backend, name):
    """Engine factors == per-node Algorithm-2 reference (f64, both
    backends; pallas runs in interpret mode on CPU)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 4), dtype=jnp.float64)
    ker = BaseKernel(name, sigma=1.5, jitter=1e-8)
    key = jax.random.PRNGKey(1)
    f = build_hck(x, levels=2, rank=8, key=key, kernel=ker,
                  config=SolveConfig(backend=backend))
    fr = build_hck_reference(x, levels=2, rank=8, key=key, kernel=ker)
    _assert_factors_close(f, fr, atol=1e-9)


def test_engine_matches_reference_shared_landmarks(f64):
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 3), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=1.0, jitter=1e-10)
    key = jax.random.PRNGKey(3)
    f = build_hck(x, levels=3, rank=8, key=key, kernel=ker,
                  shared_landmarks=True)
    fr = build_hck_reference(x, levels=3, rank=8, key=key, kernel=ker,
                             shared_landmarks=True)
    _assert_factors_close(f, fr, atol=1e-9)


def test_engine_default_config_unchanged(f64, small_problem):
    """config=None (DEFAULT_CONFIG) reproduces an explicitly-xla build —
    the refactor must not have moved the default numerics."""
    x, ker, f = small_problem
    f2 = build_hck(x, levels=3, rank=16, key=jax.random.PRNGKey(1),
                   kernel=ker, config=SolveConfig(backend="xla"))
    _assert_factors_close(f, f2, atol=0)


def test_streaming_equals_in_memory(f64):
    """ArraySource streaming build == in-memory build under the same key
    (partition/landmarks exact; factor stages to batched-solve round-off),
    with odd leaf_batch and chunk_rows exercising uneven staging."""
    x = jax.random.normal(jax.random.PRNGKey(4), (256, 5), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-8)
    key = jax.random.PRNGKey(5)
    f = build_hck(x, levels=3, rank=8, key=key, kernel=ker)
    fs = build_hck_streaming(ArraySource(np.asarray(x)), levels=3, rank=8,
                             key=key, kernel=ker, leaf_batch=3,
                             chunk_rows=23)
    _assert_factors_close(f, fs, atol=1e-12)


def test_stream_partition_equals_batched(f64):
    x = jax.random.normal(jax.random.PRNGKey(6), (128, 4), dtype=jnp.float64)
    key = jax.random.PRNGKey(7)
    from repro.core.partition import build_partition

    _, tree = build_partition(x, 3, key)
    perm, tree_s = stream_partition(ArraySource(np.asarray(x)), 3, key,
                                    chunk_rows=17)
    np.testing.assert_array_equal(np.asarray(tree.perm), perm)
    for a, b in zip(tree.thresholds, tree_s.thresholds):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fit_odd_n_padding_in_memory_vs_streaming(f64):
    """Odd n (padding required): fit and fit_streaming consume the same
    key, pad with the same duplicate-and-jitter rows, and must produce the
    same model coefficients and predictions."""
    n = 147                              # pads to 10 * 2**4 = 160
    x = jax.random.normal(jax.random.PRNGKey(8), (n, 3), dtype=jnp.float64)
    y = jnp.sin(x[:, 0]) + 0.1 * x[:, 1]
    ker = BaseKernel("gaussian", sigma=1.5, jitter=1e-8)
    key = jax.random.PRNGKey(9)
    m = krr.fit(x, y, kernel=ker, lam=1e-2, rank=8, leaf_size=10,
                key=key)
    ms = krr.fit_streaming(ArraySource(np.asarray(x)), y, kernel=ker,
                           lam=1e-2, rank=8, leaf_size=10, key=key,
                           leaf_batch=3, chunk_rows=19)
    assert m.factors.n == 160 and ms.factors.n == 160
    np.testing.assert_allclose(np.asarray(m.alpha), np.asarray(ms.alpha),
                               atol=1e-10)
    q = jax.random.normal(jax.random.PRNGKey(10), (7, 3), dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(m.predict(q)),
                               np.asarray(ms.predict(q)), atol=1e-10)


def test_pad_source_matches_pad_points(f64):
    """The streaming pad rule generates the SAME pad rows and targets as
    pad_points under the same key (host numpy vs device jnp arithmetic)."""
    from repro.core.partition import pad_points

    x = jax.random.normal(jax.random.PRNGKey(11), (37, 4), dtype=jnp.float64)
    y = jax.random.normal(jax.random.PRNGKey(12), (37,), dtype=jnp.float64)
    key = jax.random.PRNGKey(13)
    xp, yp, mask = pad_points(x, y, 8, 3, key)
    src, yps, mask_s = pad_source(ArraySource(np.asarray(x)), np.asarray(y),
                                  8, 3, key)
    np.testing.assert_array_equal(np.asarray(mask), mask_s)
    np.testing.assert_allclose(np.asarray(xp), src.chunk(0, src.n),
                               atol=1e-15)
    np.testing.assert_allclose(np.asarray(yp), yps, atol=0)
    # gather across the base/pad boundary
    rows = np.array([0, 36, 37, src.n - 1])
    np.testing.assert_allclose(src.take(rows), np.asarray(xp)[rows],
                               atol=1e-15)


def test_fit_small_n_clamps_to_one_level():
    """n <= leaf_size used to produce a degenerate 0-level fit; the sizing
    rule now clamps to one level (pad_points rejects levels == 0)."""
    x = jax.random.normal(jax.random.PRNGKey(14), (8, 3))
    y = jnp.sin(x[:, 0])
    m = krr.fit(x, y, kernel=BaseKernel(), lam=1e-2, rank=4, leaf_size=16)
    assert m.factors.levels == 1
    assert np.isfinite(np.asarray(m.predict(x[:3]))).all()


# ---------------------------------------------------------------------------
# Stage-level parity: fused Pallas bodies vs the jnp refs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["gaussian", "laplace", "imq"])
@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_build_gram_stage_parity(f64, name, dtype):
    dt = jnp.dtype(dtype)
    p = jax.random.normal(jax.random.PRNGKey(0), (5, 12, 3), dtype=dt)
    kw = dict(name=name, sigma=1.3, jitter=1e-6)
    gx, cx = get_impl("build_gram", "xla")(p, **kw)
    gp_, cp = get_impl("build_gram", "pallas")(p, **kw)
    tol = 1e-5 if dt == jnp.float32 else 1e-11
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gp_), atol=tol)
    np.testing.assert_allclose(np.asarray(cx), np.asarray(cp), atol=tol)
    # want_chol=False returns the same gram and no factor
    g2, c2 = get_impl("build_gram", "pallas")(p, want_chol=False, **kw)
    assert c2 is None
    np.testing.assert_array_equal(np.asarray(g2), np.asarray(gp_))


@pytest.mark.parametrize("name", ["gaussian", "laplace", "imq"])
@pytest.mark.parametrize("block_m", [None, 4, 12])
def test_build_cross_stage_parity(f64, name, block_m):
    from repro.core.hck import sigma_linv

    p = jax.random.normal(jax.random.PRNGKey(1), (4, 12, 3),
                          dtype=jnp.float64)
    lm = jax.random.normal(jax.random.PRNGKey(2), (4, 6, 3),
                           dtype=jnp.float64)
    kw = dict(name=name, sigma=1.1)
    _, cho = get_impl("build_gram", "xla")(lm, jitter=1e-6, **kw)
    li = sigma_linv(cho)
    ux = get_impl("build_cross", "xla")(p, lm, li, **kw)
    up = get_impl("build_cross", "pallas")(p, lm, li, block_m=block_m, **kw)
    np.testing.assert_allclose(np.asarray(ux), np.asarray(up), atol=1e-11)


def test_engine_fits_whole_system(f64):
    """End-to-end sanity: engine-built factors drive a dense-verified fit
    (K_hck from the batched engine inverts correctly)."""
    from repro.core import hmatrix

    x = jax.random.normal(jax.random.PRNGKey(15), (128, 3),
                          dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=1.5, jitter=1e-8)
    f = build_hck(x, levels=2, rank=8, key=jax.random.PRNGKey(16),
                  kernel=ker)
    a = to_dense(f)
    b = jax.random.normal(jax.random.PRNGKey(17), (f.n, 2),
                          dtype=jnp.float64)
    got = hmatrix.solve(f, b, ridge=0.1)
    want = jnp.linalg.solve(a + 0.1 * jnp.eye(f.n), b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-8)
