"""Tile-config properties and the autotune tile-DB lifecycle.

Properties (hypothesis, deterministic fallback without it):

  * ``tile_config`` tiles always fit the VMEM budget (or report not-fits
    honestly for the whole-node stages that cannot shrink);
  * row-tiled stages snap the block to a divisor of ``n0``;
  * degenerate shapes (r > n0 buckets, d = 0, k = 1) never crash.

Autotune lifecycle (against a tmp-path ``REPRO_TILE_DB``):

  * sweep -> save -> fresh DB object -> same key is a ``cached: True``
    hit with identical winner (the acceptance criterion's round-trip);
  * measured winners steer ``resolve_backend`` / ``tile_config``;
  * a corrupt DB file degrades to heuristics instead of raising.
"""
import json
import os

import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import autotune
from repro.kernels.registry import (SolveConfig, _VMEM_BUDGET,
                                    resolve_backend, tile_config)

SETTINGS = dict(max_examples=12, deadline=None)

ROW_TILED = ["leaf_matvec", "leaf_solve", "build_cross", "build_cross_dist"]
WHOLE_NODE = ["build_gram", "build_gram_dist", "leaf_factor"]


@pytest.fixture(scope="module", autouse=True)
def _isolated_db(tmp_path_factory):
    """Shield every test here from the user's real ~/.cache tile DB.

    Module-scoped (not function-scoped monkeypatch) so the hypothesis
    property tests can use it without tripping the function-scoped
    fixture health check.
    """
    path = tmp_path_factory.mktemp("autotune") / "tile_db.json"
    saved = {k: os.environ.get(k) for k in ("REPRO_TILE_DB", "REPRO_AUTOTUNE")}
    os.environ["REPRO_TILE_DB"] = str(path)
    os.environ.pop("REPRO_AUTOTUNE", None)
    autotune.reset_db()
    yield path
    for k, v in saved.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v
    autotune.reset_db()


@pytest.fixture
def tile_db(tmp_path, monkeypatch):
    """Point the autotune DB at a throwaway per-test file."""
    path = tmp_path / "tile_db.json"
    monkeypatch.setenv("REPRO_TILE_DB", str(path))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    autotune.reset_db()
    yield path
    autotune.reset_db()


# ---------------------------------------------------------------------------
# tile_config properties
# ---------------------------------------------------------------------------

@given(stage=st.sampled_from(ROW_TILED),
       n0=st.integers(8, 2048), r=st.integers(1, 256),
       k=st.integers(1, 8), itemsize=st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_row_tiled_fits_and_divides(stage, n0, r, k, itemsize):
    cfg = tile_config(stage, n0=n0, r=r, k=k, d=8, itemsize=itemsize,
                      leaf_block=None)
    assert 1 <= cfg.block_n0 <= max(n0, 8)
    if stage in ("leaf_matvec", "leaf_solve", "build_cross",
                 "build_cross_dist"):
        assert n0 % cfg.block_n0 == 0, "tile must divide the leaf"
    # shrink-to-fit: any shape small enough to shrink must land in budget
    if cfg.block_n0 > 8:
        assert cfg.fits, (stage, n0, r, k, itemsize, cfg)


@given(stage=st.sampled_from(ROW_TILED + ["oos_local", "oos_walk",
                                          "kernel_matvec"]),
       n0=st.integers(8, 512), block=st.integers(1, 512))
@settings(**SETTINGS)
def test_explicit_leaf_block_snaps(stage, n0, block):
    cfg = tile_config(stage, n0=n0, r=16, k=2, d=8, leaf_block=block)
    if stage in ROW_TILED:
        assert n0 % cfg.block_n0 == 0
        assert cfg.block_n0 <= n0
    else:   # query/row-padded stages take the block as given (>= floor)
        assert cfg.block_n0 >= 1


@given(stage=st.sampled_from(WHOLE_NODE), n0=st.integers(8, 1024))
@settings(**SETTINGS)
def test_whole_node_stages_report_honest_vmem(stage, n0):
    cfg = tile_config(stage, n0=n0, r=n0, k=1, d=8)
    assert cfg.block_n0 == n0, "whole-node stages cannot row-tile"
    assert cfg.fits == (cfg.vmem_bytes <= _VMEM_BUDGET)


@pytest.mark.parametrize("stage", ROW_TILED + WHOLE_NODE
                         + ["oos_local", "oos_walk", "kernel_matvec"])
def test_degenerate_shapes_do_not_crash(stage):
    # r > n0, d = 0, k = 1 — the corners the builders can hand over
    for n0, r, k, d in [(8, 32, 1, 0), (8, 1, 1, 0), (16, 16, 1, 0)]:
        cfg = tile_config(stage, n0=n0, r=r, k=k, d=d)
        assert cfg.block_n0 >= 1
        assert cfg.vmem_bytes >= 0


# ---------------------------------------------------------------------------
# autotune DB lifecycle
# ---------------------------------------------------------------------------

def test_bucket_key_pow2_and_stable():
    k1 = autotune.bucket_key("leaf_matvec", "cpu", "float32",
                             n0=100, r=17, k=3, d=5)
    k2 = autotune.bucket_key("leaf_matvec", "cpu", "float32",
                             n0=128, r=32, k=4, d=8)
    assert k1 == k2, "shapes in one pow2 bucket share a key"
    assert "n0=128" in k1 and "r=32" in k1


def test_sweep_then_cache_hit_roundtrip(tile_db):
    rec = autotune.autotune_stage("leaf_matvec", n0=32, r=8, k=1, d=4,
                                  batch=2, repeats=1)
    assert rec["cached"] is False
    assert rec["backend"] in ("xla", "pallas")
    assert rec["best_s"] > 0
    assert os.path.exists(tile_db), "sweep must persist the DB"

    autotune.reset_db()     # force a re-read from disk
    hit = autotune.autotune_stage("leaf_matvec", n0=32, r=8, k=1, d=4,
                                  batch=2, repeats=1)
    assert hit["cached"] is True
    assert hit["backend"] == rec["backend"]
    assert hit["block"] == rec["block"]
    assert hit["best_s"] == rec["best_s"], "hit returns the stored record"

    # a nearby shape in the same pow2 bucket is the same cache line
    near = autotune.autotune_stage("leaf_matvec", n0=30, r=7, k=1, d=3,
                                   batch=2, repeats=1)
    assert near["cached"] is True


def test_measured_winner_steers_registry(tile_db):
    db = autotune.get_db()
    key = autotune.bucket_key("leaf_matvec", autotune.device_kind(),
                              "float32", n0=64, r=16, k=1, d=0)
    db.put(key, {"stage": "leaf_matvec", "backend": "xla", "block": None,
                 "pallas_block": 16, "platform": "cpu",
                 "rates": {"flops_per_s": 1e9, "bytes_per_s": 1e9}})
    db.save()

    cfg = SolveConfig(backend="auto", interpret=False)
    got = resolve_backend(cfg, "leaf_matvec", dtype=jnp.float32,
                          n0=64, r=16, k=1)
    assert got == "xla", "measured xla winner must override heuristics"
    tc = tile_config("leaf_matvec", n0=64, r=16, k=1, d=0)
    assert tc.block_n0 == 16, "measured pallas tile steers tile_config"

    # flip the record to pallas: auto must follow (divisibility holding)
    db.put(key, {"stage": "leaf_matvec", "backend": "pallas", "block": 16,
                 "pallas_block": 16, "platform": "cpu", "rates": {}})
    assert resolve_backend(cfg, "leaf_matvec", dtype=jnp.float32,
                           n0=64, r=16, k=1) == "pallas"


def test_repro_autotune_0_disables_lookups(tile_db, monkeypatch):
    db = autotune.get_db()
    key = autotune.bucket_key("leaf_matvec", autotune.device_kind(),
                              "float32", n0=64, r=16, k=1, d=0)
    db.put(key, {"stage": "leaf_matvec", "backend": "pallas", "block": 8,
                 "pallas_block": 8, "platform": "cpu", "rates": {}})
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert autotune.lookup_block("leaf_matvec", n0=64, r=16, k=1) is None
    tc = tile_config("leaf_matvec", n0=64, r=16, k=1, d=0)
    assert tc.block_n0 == 64, "lookups off -> heuristic whole leaf"


def test_corrupt_db_degrades_to_heuristics(tile_db):
    tile_db.write_text("{not json at all")
    autotune.reset_db()
    db = autotune.get_db()
    assert db.corrupt is True
    assert db.entries == {}
    # registry consults must not raise and must fall back
    assert autotune.lookup_block("leaf_matvec", n0=64, r=16, k=1) is None
    tc = tile_config("leaf_matvec", n0=64, r=16, k=1, d=0)
    assert tc.block_n0 == 64
    # a fresh sweep repairs the file
    autotune.autotune_stage("leaf_project", n0=16, r=8, k=1, batch=2,
                            repeats=1, db=db)
    blob = json.loads(tile_db.read_text())
    assert blob["entries"], "save() rewrites a corrupt file"


def test_calibrated_peaks_aggregates_platform(tile_db):
    db = autotune.get_db()
    for i, (plat, f, b) in enumerate([("cpu", 1e9, 2e9), ("cpu", 3e9, 1e9),
                                      ("gpu", 9e12, 9e11)]):
        db.put(f"k{i}", {"stage": "s", "platform": plat,
                         "rates": {"flops_per_s": f, "bytes_per_s": b}})
    peaks = autotune.calibrated_peaks("cpu")
    assert peaks == {"flops_per_s": 3e9, "bytes_per_s": 2e9}
    assert autotune.calibrated_peaks("tpu") is None
