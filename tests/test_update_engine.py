"""Property tests pinning the online-update math (DESIGN.md §10).

The contract under test: routing new points down the FROZEN tree and
extending only the leaf factors (repro.core.update.insert + the bordered
``leaf_update`` stage behind hmatrix.invert_extend) must agree with a
from-scratch rebuild of the leaf stages on the union
(repro.core.update.refit_frozen — same tree, landmarks, Sigma, W, and
the same fit-time frozen λ′ diagonal) to float64 round-off: factors at
1e-10, end-to-end predictions at 1e-6.  Padding makes the update
reversible: downdate(insert(f)) == f BITWISE.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hmatrix, krr, oos, update
from repro.core.kernels_fn import BaseKernel
from repro.core.partition import route
from repro.kernels.registry import SolveConfig

SETTINGS = dict(max_examples=6, deadline=None)


@pytest.fixture(autouse=True, scope="module")
def _f64():
    # the hypothesis fallback wraps @given tests zero-arg, so the shared
    # f64 fixture cannot be requested per-test; autouse covers the module
    jax.config.update("jax_enable_x64", True)
    yield


def _target(x):
    return jnp.sin(x[:, 0]) + 0.25 * jnp.cos(2.0 * x[:, 1])


@functools.lru_cache(maxsize=2)
def _model(n=256, d=5, lam=1e-2):
    """One fitted f64 model per module run (n0=32, P=8 leaves)."""
    jax.config.update("jax_enable_x64", True)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-8)
    model = krr.fit(x, _target(x), kernel=ker, lam=lam, rank=16,
                    leaf_size=32, levels=3, key=jax.random.PRNGKey(1))
    return model, x


def _arrivals(seed, q, d=5, scale=1.0):
    x_new = scale * jax.random.normal(jax.random.PRNGKey(seed), (q, d),
                                      dtype=jnp.float64)
    return x_new, _target(x_new)


def _oracle(model, x_new, y_new, key):
    """From-scratch rebuild under the frozen-λ′ convention.

    Replays the SAME insert (same key -> bit-identical padding rows),
    then rebuilds Adiag/U from scratch on the union (refit_frozen) and
    solves directly — the reference every incremental path must match.
    """
    f, lam, cfg = model.factors, model.lam, model.solve_config
    base = model.base_leaf_size
    tn = y_new if y_new.ndim > 1 else y_new[:, None]
    ys = hmatrix.matvec(f, model.alpha, cfg) + lam * model.alpha
    f2, ys2, rec = update.insert(f, x_new, model.kernel, key=key, config=cfg,
                                 y_new=tn, y_sorted=ys, jitter_rows=base)
    f_ref = update.refit_frozen(f2, model.kernel, cfg, jitter_rows=base)
    alpha = hmatrix.solve(f_ref, ys2, ridge=lam, config=cfg)
    plan = oos.prepare(f_ref, alpha, cfg)
    oracle = krr.HCKRegressor(model.kernel, f_ref, plan, alpha,
                              squeeze=model.squeeze, solve_config=cfg,
                              lam=lam, base_leaf_size=base)
    return oracle, f2, f_ref, rec


QUERIES = None


def _queries(d=5):
    global QUERIES
    if QUERIES is None:
        QUERIES = jax.random.normal(jax.random.PRNGKey(77), (64, d),
                                    dtype=jnp.float64)
    return QUERIES


# ---------------------------------------------------------------------------
# insert-then-predict == from-scratch rebuild on the union
# ---------------------------------------------------------------------------

@given(q=st.integers(1, 23), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_insert_then_predict_matches_refit_oracle(q, seed):
    """Incremental insert of q in [1, 23] points (odd, even, prime batch
    sizes alike) matches the from-scratch leaf rebuild: factors to 1e-10,
    predictions to 1e-6 — the headline acceptance gate in f64."""
    model, _ = _model()
    x_new, y_new = _arrivals(seed, q)
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    m2, info = model.update(x_new, y_new, key=key)
    oracle, f2, f_ref, rec = _oracle(model, x_new, y_new, key)

    # same key -> the incremental model holds the bit-identical union
    np.testing.assert_array_equal(np.asarray(m2.factors.x_sorted),
                                  np.asarray(f2.x_sorted))
    assert info.record.k == rec.k and int(rec.counts.sum()) == q
    # factor-level parity: the bordered extension vs the from-scratch stage
    np.testing.assert_allclose(np.asarray(m2.factors.adiag),
                               np.asarray(f_ref.adiag), rtol=0, atol=1e-10)
    np.testing.assert_allclose(np.asarray(m2.factors.u),
                               np.asarray(f_ref.u), rtol=0, atol=1e-10)
    # end-to-end parity on fresh queries
    np.testing.assert_allclose(np.asarray(m2.predict(_queries())),
                               np.asarray(oracle.predict(_queries())),
                               rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_repeated_inserts_accumulate(seed):
    """Three sequential inserts stay on the frozen-λ′ convention: the
    final model matches one from-scratch rebuild of its own union, and
    the leaf size grows by the sum of the per-round paddings."""
    model, _ = _model()
    m = model
    grown = 0
    for i, q in enumerate((5, 12, 7)):
        x_new, y_new = _arrivals(seed + i, q)
        m, info = m.update(x_new, y_new, key=jax.random.PRNGKey(1000 + i))
        grown += info.record.k
        assert int(info.record.counts.sum()) == q
    assert m.factors.leaf_size == model.factors.leaf_size + grown
    assert m.base_leaf_size == model.base_leaf_size

    # oracle on the accumulated union (factors already in hand)
    f_ref = update.refit_frozen(m.factors, m.kernel, m.solve_config,
                                jitter_rows=m.base_leaf_size)
    ys = hmatrix.matvec(m.factors, m.alpha, m.solve_config) + m.lam * m.alpha
    alpha = hmatrix.solve(f_ref, ys, ridge=m.lam, config=m.solve_config)
    plan = oos.prepare(f_ref, alpha, m.solve_config)
    oracle = krr.HCKRegressor(m.kernel, f_ref, plan, alpha,
                              squeeze=m.squeeze, solve_config=m.solve_config,
                              lam=m.lam, base_leaf_size=m.base_leaf_size)
    np.testing.assert_allclose(np.asarray(m.predict(_queries())),
                               np.asarray(oracle.predict(_queries())),
                               rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1), q=st.integers(2, 9))
@settings(**SETTINGS)
def test_duplicate_training_points_insert(seed, q):
    """Inserting EXACT copies of training rows (the worst conditioning
    case — the appended Schur block is a near-duplicate of existing rows)
    still matches the oracle: the frozen λ′ diagonal keeps the bordered
    extension positive definite."""
    model, x = _model()
    rows = np.asarray(jax.random.randint(jax.random.PRNGKey(seed),
                                         (q,), 0, x.shape[0]))
    x_new = x[rows]
    y_new = _target(x_new)
    key = jax.random.PRNGKey(seed + 3)
    m2, info = model.update(x_new, y_new, key=key)
    oracle, _, _, _ = _oracle(model, x_new, y_new, key)
    assert np.isfinite(np.asarray(m2.alpha)).all()
    np.testing.assert_allclose(np.asarray(m2.predict(_queries())),
                               np.asarray(oracle.predict(_queries())),
                               rtol=1e-6, atol=1e-6)


def test_all_arrivals_in_one_leaf():
    """q copies of a single training point all route to one leaf (routing
    is a pure function of the point), so k == q there and every other
    leaf is pure padding — the maximally unbalanced insert."""
    model, x = _model()
    q = 6
    x_new = jnp.tile(x[17][None], (q, 1))
    y_new = _target(x_new)
    leaf = int(route(model.factors.tree, x[17][None])[0])
    key = jax.random.PRNGKey(9)
    m2, info = model.update(x_new, y_new, key=key)
    counts = info.record.counts
    assert counts[leaf] == q and counts.sum() == q and info.record.k == q
    oracle, _, _, _ = _oracle(model, x_new, y_new, key)
    np.testing.assert_allclose(np.asarray(m2.predict(_queries())),
                               np.asarray(oracle.predict(_queries())),
                               rtol=1e-6, atol=1e-6)


def test_empty_insert_is_noop():
    """A (0, d) batch is an exact no-op: the SAME model object comes back
    and insert returns the SAME factors object (no recompute at all)."""
    model, _ = _model()
    x_new = jnp.zeros((0, 5), dtype=jnp.float64)
    y_new = jnp.zeros((0,), dtype=jnp.float64)
    m2, info = model.update(x_new, y_new, key=jax.random.PRNGKey(0))
    assert m2 is model
    assert info.record.k == 0 and info.iterations == 0 and info.converged

    f2, ys2, rec = update.insert(model.factors, x_new, model.kernel,
                                 key=jax.random.PRNGKey(0))
    assert f2 is model.factors and rec.k == 0


# ---------------------------------------------------------------------------
# reversibility + routing of outside-the-hull batches
# ---------------------------------------------------------------------------

@given(q=st.integers(1, 17), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_insert_downdate_roundtrip_bitwise(q, seed):
    """downdate(insert(f, batch)) == f BITWISE: the bordered extension
    never touches a leading block, so removing the appended rows is a
    pure slice that restores every factor exactly."""
    model, _ = _model()
    f = model.factors
    x_new, _ = _arrivals(seed, q)
    f2, _, rec = update.insert(f, x_new, model.kernel,
                               key=jax.random.PRNGKey(seed))
    assert f2.leaf_size == f.leaf_size + rec.k
    f3 = update.downdate(f2, rec.k)
    np.testing.assert_array_equal(np.asarray(f3.x_sorted),
                                  np.asarray(f.x_sorted))
    np.testing.assert_array_equal(np.asarray(f3.tree.perm),
                                  np.asarray(f.tree.perm))
    np.testing.assert_array_equal(np.asarray(f3.u), np.asarray(f.u))
    np.testing.assert_array_equal(np.asarray(f3.adiag), np.asarray(f.adiag))
    assert update.downdate(f2, 0) is f2
    with pytest.raises(ValueError, match="cannot remove"):
        update.downdate(f2, f2.leaf_size)


def test_out_of_hull_batch_routes_to_boundary_leaves():
    """A batch entirely OUTSIDE the training hull (±100 on every axis,
    the group_by_leaf edge case) routes every point to a well-defined
    boundary leaf under the t > thr / ties-go-LEFT rule, and the insert
    still matches the oracle — no NaNs, no dropped points."""
    model, _ = _model()
    d = 5
    far = jnp.concatenate([
        jnp.full((3, d), 100.0, dtype=jnp.float64),
        jnp.full((3, d), -100.0, dtype=jnp.float64),
        100.0 * jnp.eye(d, dtype=jnp.float64)[:2],
    ])
    y_new = _target(far)
    leaves = np.asarray(route(model.factors.tree, far))
    p = model.factors.num_leaves
    assert ((0 <= leaves) & (leaves < p)).all()

    key = jax.random.PRNGKey(4)
    m2, info = model.update(far, y_new, key=key)
    np.testing.assert_array_equal(
        info.record.counts, np.bincount(leaves, minlength=p))
    assert int(info.record.counts.sum()) == far.shape[0]
    oracle, _, _, _ = _oracle(model, far, y_new, key)
    np.testing.assert_allclose(np.asarray(m2.predict(_queries())),
                               np.asarray(oracle.predict(_queries())),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# warm-started re-solve (refresh="stale")
# ---------------------------------------------------------------------------

def test_stale_refresh_warm_start_beats_cold():
    """The cheap path — no re-factorization, CG warm-started from the old
    alpha under the stale Schur-congruence preconditioner — converges in
    at most HALF the iterations a from-scratch CG (no preconditioner, no
    x0) pays, and lands on the same predictions as the exact path."""
    model, _ = _model()
    x_new, y_new = _arrivals(21, 16)
    key = jax.random.PRNGKey(21)
    m_exact, _ = model.update(x_new, y_new, key=key)
    m_stale, info = model.update(x_new, y_new, key=key, refresh="stale",
                                 measure_cold=True, tol=1e-8, maxiter=300)
    assert info.converged
    assert info.cold_iterations is not None
    assert info.iterations * 2 <= info.cold_iterations
    np.testing.assert_allclose(np.asarray(m_stale.predict(_queries())),
                               np.asarray(m_exact.predict(_queries())),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# policy + error paths
# ---------------------------------------------------------------------------

def test_rebuild_policy_thresholds():
    pol = update.RebuildPolicy(max_leaf_growth=0.5, max_warm_iters=20,
                               max_update_error=1e-4)
    ok = dict(base_leaf_size=32, leaf_size=40)     # growth 0.25
    assert not pol.should_rebuild(**ok)
    assert pol.should_rebuild(base_leaf_size=32, leaf_size=49)  # > 0.5
    assert pol.should_rebuild(**ok, warm_iters=21)
    assert not pol.should_rebuild(**ok, warm_iters=20)
    assert pol.should_rebuild(**ok, update_error=1e-3)
    # None disables the optional checks entirely
    pol2 = update.RebuildPolicy(max_leaf_growth=0.5)
    assert not pol2.should_rebuild(**ok, warm_iters=10**6, update_error=1.0)


def test_insert_error_paths():
    model, _ = _model()
    x_new, y_new = _arrivals(0, 3)
    with pytest.raises(ValueError, match="y_sorted"):
        update.insert(model.factors, x_new, model.kernel,
                      key=jax.random.PRNGKey(0), y_new=y_new[:, None])
    legacy = dataclasses.replace(model, lam=None)
    with pytest.raises(ValueError, match="no fit ridge"):
        krr.fit_incremental(legacy, x_new, y_new)
    with pytest.raises(ValueError, match="refresh"):
        model.update(x_new, y_new, refresh="bogus")


def test_update_rejects_unknown_class_labels():
    """Classification models refuse arrivals with labels outside the
    fitted classes (the ±1 / one-vs-all encoding is frozen at fit time)."""
    jax.config.update("jax_enable_x64", True)
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 4), dtype=jnp.float64)
    y = (x[:, 0] > 0).astype(jnp.int32)
    model = krr.fit(x, y, kernel=BaseKernel("gaussian", sigma=2.0,
                                            jitter=1e-8),
                    lam=1e-2, rank=8, leaf_size=16, levels=3,
                    key=jax.random.PRNGKey(1), classification=True)
    x_new = jax.random.normal(jax.random.PRNGKey(2), (4, 4),
                              dtype=jnp.float64)
    m2, _ = model.update(x_new, (x_new[:, 0] > 0).astype(jnp.int32),
                         key=jax.random.PRNGKey(3))
    assert m2.factors.n > model.factors.n
    with pytest.raises(ValueError, match="outside the fitted classes"):
        model.update(x_new, jnp.full((4,), 7, jnp.int32),
                     key=jax.random.PRNGKey(3))


# ---------------------------------------------------------------------------
# mixed-precision indefiniteness regression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision,lam,jitter,max_resid", [
    ("f32", 1e-2, 1e-5, 1e-4),
    ("bf16", 1e-1, 1e-4, 1e-2),
])
def test_update_definite_at_documented_jitter_floor(precision, lam, jitter,
                                                    max_resid):
    """Regression for the minimum-jitter floor under reduced precision
    (the launch/train.py convention: bf16 needs λ=1e-1 / jitter=1e-4,
    f32 runs at λ=1e-2 / jitter=1e-5).  At the documented floor the
    bordered extension must stay positive definite: finite factors,
    finite predictions, small solve residual — below the floor the leaf
    Cholesky goes indefinite in half precision."""
    cfg = SolveConfig(precision=precision)
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 5),
                          dtype=jnp.float32)
    y = _target(x).astype(jnp.float32)
    model = krr.fit(x, y, kernel=BaseKernel("gaussian", sigma=2.0,
                                            jitter=jitter),
                    lam=lam, rank=16, leaf_size=32, levels=3,
                    key=jax.random.PRNGKey(1), solve_config=cfg)
    x_new = jax.random.normal(jax.random.PRNGKey(5), (12, 5),
                              dtype=jnp.float32)
    m2, info = model.update(x_new, _target(x_new).astype(jnp.float32),
                            key=jax.random.PRNGKey(6))
    assert np.isfinite(np.asarray(m2.factors.adiag)).all()
    assert np.isfinite(np.asarray(m2.factors.u)).all()
    assert np.isfinite(np.asarray(m2.alpha)).all()
    assert info.residual < max_resid
    z = m2.predict(jax.random.normal(jax.random.PRNGKey(7), (32, 5),
                                     dtype=jnp.float32))
    assert np.isfinite(np.asarray(z)).all()
