"""Matvec-free iterative solver subsystem (repro.solvers).

Covers the three pillars against dense oracles:
  * the chunked exact-kernel operator / kernel_matvec stage (xla vs
    pallas-interpret vs dense gram, all base kernels, odd shapes),
  * HCK-preconditioned CG (fit_exact vs jnp.linalg.solve, the >=4x
    iteration-ratio property, the EigenPro rival, dist_solve parity with
    the deleted legacy helper),
  * stochastic Lanczos quadrature (logdet across a ridge grid vs the
    Algorithm-2 exact recursion, mle_grid logdet="slq" vs the exact
    surface),
plus the fit_nystrom lambda-scaling regression pinned to an explicit
dual solve.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import baselines, gp, hmatrix, krr
from repro.core.kernels_fn import BaseKernel
from repro.kernels.registry import SolveConfig, get_impl, registered
from repro.solvers import (ExactKernelOp, HCKOp, eigenpro_solve, lanczos,
                           pcg, slq_logdet)


# ---------------------------------------------------------------------------
# kernel_matvec stage + ExactKernelOp
# ---------------------------------------------------------------------------

def test_kernel_matvec_stage_registered_both_backends():
    assert ("kernel_matvec", "xla") in registered("kernel_matvec")
    assert ("kernel_matvec", "pallas") in registered("kernel_matvec")


@pytest.mark.parametrize("name", ["gaussian", "laplace", "imq"])
def test_kernel_matvec_stage_parity(f64, name):
    """Pallas body == dtype-preserving ref == dense cross @ v (f64)."""
    key = jax.random.PRNGKey(0)
    xc = jax.random.normal(key, (70, 5), dtype=jnp.float64)
    y = jax.random.normal(jax.random.PRNGKey(1), (190, 5), dtype=jnp.float64)
    v = jax.random.normal(jax.random.PRNGKey(2), (190, 3), dtype=jnp.float64)
    ker = BaseKernel(name, sigma=1.7)
    want = ker.cross(xc, y) @ v
    got_x = get_impl("kernel_matvec", "xla")(xc, y, v, name=name, sigma=1.7)
    got_p = get_impl("kernel_matvec", "pallas")(
        xc, y, v, name=name, sigma=1.7, interpret=True)
    assert float(jnp.max(jnp.abs(got_x - want))) < 1e-10
    assert float(jnp.max(jnp.abs(got_p - want))) < 1e-10


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_exact_operator_matches_dense_gram(f64, backend):
    """ExactKernelOp.matvec == (kernel.gram) @ v, odd n, odd chunking."""
    key = jax.random.PRNGKey(0)
    n = 333
    x = jax.random.normal(key, (n, 4), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-7)
    op = ExactKernelOp(x, ker, SolveConfig(backend=backend), row_chunk=100)
    v = jax.random.normal(jax.random.PRNGKey(1), (n, 2), dtype=jnp.float64)
    want = ker.gram(x) @ v
    assert float(jnp.max(jnp.abs(op.matvec(v) - want))) < 1e-10
    # 1-D round trip + cross form
    assert op.matvec(v[:, 0]).shape == (n,)
    q = jax.random.normal(jax.random.PRNGKey(2), (17, 4), dtype=jnp.float64)
    want_q = ker.cross(q, x) @ v
    assert float(jnp.max(jnp.abs(op.cross_matvec(q, v) - want_q))) < 1e-10


def test_hck_op_matches_hmatrix(f64, small_problem):
    _, _, f = small_problem
    op = HCKOp(f)
    v = jax.random.normal(jax.random.PRNGKey(3), (f.n, 2), dtype=jnp.float64)
    assert jnp.allclose(op.matvec(v), hmatrix.matvec(f, v))
    assert op.shape == (f.n, f.n)


# ---------------------------------------------------------------------------
# PCG engine
# ---------------------------------------------------------------------------

def test_pcg_matches_dense_solve_multirhs(f64):
    key = jax.random.PRNGKey(0)
    n = 300
    x = jax.random.normal(key, (n, 4), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-8)
    op = ExactKernelOp(x, ker, row_chunk=128)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, 3), dtype=jnp.float64)
    lam = 0.1
    res = pcg(op.matvec, b, ridge=lam, tol=1e-12, maxiter=600)
    want = jnp.linalg.solve(ker.gram(x) + lam * jnp.eye(n), b)
    assert bool(res.converged)
    assert float(jnp.max(jnp.abs(res.x - want))) < 1e-8
    # trace bookkeeping: starts at 1, frozen past the exit iteration
    it = int(res.iterations)
    assert float(res.residuals[0]) == pytest.approx(1.0)
    assert float(res.residuals[it]) <= 1e-12
    assert jnp.all(res.residuals[it:] == res.residuals[it])


def test_pcg_fixed_iteration_mode(f64):
    """tol=0 runs exactly maxiter iterations (legacy dist semantics)."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (50, 50), dtype=jnp.float64)
    a = a @ a.T + 50 * jnp.eye(50)
    b = jnp.ones((50,), jnp.float64)
    res = pcg(lambda v: a @ v, b, tol=0.0, maxiter=7)
    assert int(res.iterations) == 7


def test_fit_exact_matches_dense_both_backends(f64):
    """Acceptance gate (scaled down): fit_exact == dense solve to 1e-6."""
    key = jax.random.PRNGKey(0)
    n = 512
    x = jax.random.normal(key, (n, 4), dtype=jnp.float64)
    y = jnp.sin(x[:, 0]) + 0.25 * jnp.cos(2 * x[:, 1])
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
    lam = 1e-2
    want = jnp.linalg.solve(ker.gram(x) + lam * jnp.eye(n), y[:, None])
    for backend in ("xla", "pallas"):
        m = krr.fit_exact(x, y, kernel=ker, lam=lam, rank=64,
                          key=jax.random.PRNGKey(1), tol=1e-9, maxiter=400,
                          solve_config=SolveConfig(backend=backend))
        assert bool(m.result.converged), backend
        assert float(jnp.max(jnp.abs(m.alpha - want))) < 1e-6, backend
        # predict through the chunked cross operator matches the dense form
        q = x[:33]
        pred = m.predict(q)
        want_q = (ker.cross(q, x) @ want)[:, 0]
        assert float(jnp.max(jnp.abs(pred - want_q))) < 1e-6, backend


def test_fit_exact_odd_n_padded_preconditioner(f64):
    """n that does not fill the tree: weighted embed/extract stays SPD
    and converges to the dense solution of the ORIGINAL problem."""
    key = jax.random.PRNGKey(0)
    n = 450
    x = jax.random.normal(key, (n, 4), dtype=jnp.float64)
    y = jnp.sin(x[:, 0])
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
    lam = 1e-2
    m = krr.fit_exact(x, y, kernel=ker, lam=lam, rank=64,
                      key=jax.random.PRNGKey(1), tol=1e-9, maxiter=600)
    want = jnp.linalg.solve(ker.gram(x) + lam * jnp.eye(n), y[:, None])
    assert bool(m.result.converged)
    assert float(jnp.max(jnp.abs(m.alpha - want))) < 1e-6


def _iteration_ratio(n, *, rank, lam, tol, maxiter=3000):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, 4), dtype=jnp.float64)
    y = jnp.sin(x[:, 0]) + 0.25 * jnp.cos(2 * x[:, 1])
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
    kwargs = dict(kernel=ker, lam=lam, rank=rank, key=jax.random.PRNGKey(1),
                  tol=tol, maxiter=maxiter)
    m_pc = krr.fit_exact(x, y, **kwargs)
    m_pl = krr.fit_exact(x, y, precondition=False, **kwargs)
    assert bool(m_pc.result.converged) and bool(m_pl.result.converged)
    return int(m_pl.result.iterations) / max(int(m_pc.result.iterations), 1)


def test_hck_precond_iteration_ratio(f64):
    """HCK preconditioning cuts CG iterations >=4x (tier-1 scale)."""
    assert _iteration_ratio(2048, rank=128, lam=1e-2, tol=1e-6) >= 4.0


@pytest.mark.slow
def test_hck_precond_iteration_ratio_4096(f64):
    """The acceptance-criteria property at full n=4096 scale."""
    assert _iteration_ratio(4096, rank=128, lam=1e-2, tol=1e-6) >= 4.0


def test_eigenpro_solves_exact_krr(f64):
    """The truncated-eigenspectrum rival reaches the dense solution."""
    key = jax.random.PRNGKey(0)
    n = 512
    x = jax.random.normal(key, (n, 4), dtype=jnp.float64)
    y = jnp.sin(x[:, 0])[:, None]
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
    lam = 5e-2
    op = ExactKernelOp(x, ker, row_chunk=256)
    res = eigenpro_solve(op, y, ridge=lam, key=jax.random.PRNGKey(2),
                         n_components=96, subsample=384, tol=1e-8,
                         maxiter=400)
    want = jnp.linalg.solve(ker.gram(x) + lam * jnp.eye(n), y)
    assert bool(res.converged)
    assert float(jnp.max(jnp.abs(res.x - want))) < 1e-5
    # the whole point of the preconditioner: far fewer iterations than
    # the plain-Richardson spectral-radius bound lam1/(lam + tail)
    assert int(res.iterations) < 200


def test_fit_exact_rejects_undersized_preconditioner_tree(f64):
    """Explicit levels/leaf_size below capacity raise a clear error
    instead of crashing inside the padding draw."""
    x = jnp.zeros((600, 3), jnp.float64)
    y = jnp.zeros((600,), jnp.float64)
    ker = BaseKernel("gaussian", sigma=1.0)
    with pytest.raises(ValueError, match="capacity"):
        krr.fit_exact(x, y, kernel=ker, lam=1e-2, rank=32, levels=2,
                      maxiter=1)


def test_fit_exact_classification_binary(f64):
    key = jax.random.PRNGKey(0)
    n = 256
    x = jax.random.normal(key, (n, 4), dtype=jnp.float64)
    labels = (x[:, 0] > 0).astype(jnp.int32)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
    m = krr.fit_exact(x, labels, kernel=ker, lam=1e-2, rank=32,
                      key=jax.random.PRNGKey(1), classification=True,
                      tol=1e-8, maxiter=300)
    pred = m.predict_class(x)
    assert float(jnp.mean((pred == labels).astype(jnp.float32))) > 0.95


# ---------------------------------------------------------------------------
# dist_solve parity with the deleted legacy helper
# ---------------------------------------------------------------------------

def _legacy_dist_solve_cg(matvec_fn, b, *, ridge, iters, precond=None):
    """Verbatim transcription of the deleted launch.dist_hck.dist_solve_cg."""
    def amv(v):
        return matvec_fn(v) + ridge * v

    x = jnp.zeros_like(b)
    r = b - amv(x)
    z = precond(r) if precond else r
    p = z

    def body(_, carry):
        x, r, z, p = carry
        ap = amv(p)
        rz = jnp.sum(r * z)
        alpha = rz / jnp.maximum(jnp.sum(p * ap), 1e-30)
        x = x + alpha * p
        r_new = r - alpha * ap
        z_new = precond(r_new) if precond else r_new
        beta = jnp.sum(r_new * z_new) / jnp.maximum(rz, 1e-30)
        p = z_new + beta * p
        return x, r_new, z_new, p

    x, r, z, p = jax.lax.fori_loop(0, iters, body, (x, r, z, p))
    return x


def test_dist_solve_parity_with_legacy_helper(f64):
    """dist_solve(flexible=False) == the old fixed-iteration CG loop."""
    from repro.launch import dist_hck

    key = jax.random.PRNGKey(0)
    n = 160
    a = jax.random.normal(key, (n, n), dtype=jnp.float64)
    a = a @ a.T / n + 0.5 * jnp.eye(n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,), dtype=jnp.float64)
    d_inv = 1.0 / (jnp.diag(a) + 0.3)

    def mv(v):
        return a @ v

    for pc in (None, lambda r: d_inv * r):
        for iters in (5, 40):
            want = _legacy_dist_solve_cg(mv, b, ridge=0.3, iters=iters,
                                         precond=pc)
            got = dist_hck.dist_solve(mv, b, ridge=0.3, iters=iters,
                                      precond=pc, flexible=False)
            assert float(jnp.max(jnp.abs(got - want))) < 1e-12
    # default (flexible) form agrees at convergence with the dense solve
    got = dist_hck.dist_solve(mv, b, ridge=0.3, iters=120)
    xref = jnp.linalg.solve(a + 0.3 * jnp.eye(n), b)
    assert float(jnp.max(jnp.abs(got - xref))) < 1e-9


def test_dist_solve_injectable_all_reduce(f64):
    """The injected reduction is USED: a sum-preserving wrapper changes
    nothing; psum-style doubling over a fake 2-device axis still solves
    the (block-replicated) system."""
    from repro.launch import dist_hck

    key = jax.random.PRNGKey(0)
    n = 96
    a = jax.random.normal(key, (n, n), dtype=jnp.float64)
    a = a @ a.T / n + jnp.eye(n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,), dtype=jnp.float64)
    calls = []

    def all_reduce(s):
        calls.append(1)
        return s

    got = dist_hck.dist_solve(lambda v: a @ v, b, ridge=0.2, iters=60,
                              all_reduce=all_reduce)
    xref = jnp.linalg.solve(a + 0.2 * jnp.eye(n), b)
    assert calls, "all_reduce was never invoked"
    assert float(jnp.max(jnp.abs(got - xref))) < 1e-9


# ---------------------------------------------------------------------------
# SLQ logdet
# ---------------------------------------------------------------------------

def test_lanczos_recovers_small_dense_spectrum(f64):
    """Full-reorthogonalized Lanczos at iters=n reproduces eigh exactly."""
    key = jax.random.PRNGKey(0)
    n = 24
    a = jax.random.normal(key, (n, n), dtype=jnp.float64)
    a = a @ a.T + jnp.eye(n)
    v0 = jnp.ones((n,), jnp.float64)
    alphas, betas = lanczos(lambda v: a @ v, v0, n)
    t = jnp.diag(alphas) + jnp.diag(betas, 1) + jnp.diag(betas, -1)
    want = jnp.linalg.eigvalsh(a)
    got = jnp.linalg.eigvalsh(t)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-8


def test_slq_logdet_ridge_grid_vs_exact(f64, small_problem):
    """SLQ through the Algorithm-1 matvec vs the Algorithm-2 exact logdet
    across a ridge grid — one Lanczos pass serves every ridge."""
    _, _, f = small_problem
    ridges = jnp.asarray([1e-2, 1e-1, 1.0], jnp.float64)
    got = slq_logdet(HCKOp(f).matvec, f.n, ridges=ridges, probes=32,
                     iters=64, key=jax.random.PRNGKey(7),
                     dtype=jnp.float64)
    for g, ridge in enumerate(ridges):
        want = float(hmatrix.invert(f, ridge).logabsdet)
        # tolerance per point: logdet is extensive (O(n)), so gate the
        # nats-per-point error rather than a raw relative (want can
        # cross zero inside the grid); the small-ridge end carries the
        # residual Lanczos bias from the near-jitter eigenvalue cluster
        assert abs(float(got[g]) - want) / f.n < 0.025, \
            (g, float(got[g]), want)


def test_mle_grid_slq_matches_exact_surface(f64):
    """Acceptance gate: logdet='slq' agrees with the exact path to 1%
    relative NLL while never running the per-ridge exact recursion."""
    key = jax.random.PRNGKey(0)
    n = 1024
    x = jax.random.normal(key, (n, 4), dtype=jnp.float64)
    y = jnp.sin(x[:, 0]) + 0.25 * jnp.cos(2 * x[:, 1])
    kwargs = dict(levels=3, rank=64, key=jax.random.PRNGKey(1),
                  sigmas=[1.0, 2.0], noises=[1e-2, 1e-1, 1.0])
    exact = gp.mle_grid(x, y, **kwargs)
    slq = gp.mle_grid(x, y, logdet="slq", **kwargs)
    # NLL is EXTENSIVE (O(n) nats): gate the relative error against the
    # surface's natural scale max(|NLL|, n) — individual entries cross
    # zero inside the grid (the 0.5·n·log 2π offset nearly cancels
    # there), where a raw entrywise relative would measure probe noise
    # against an accidental near-zero denominator
    rel = jnp.abs(slq - exact) / jnp.maximum(jnp.abs(exact), float(n))
    assert float(jnp.max(rel)) < 0.01, (exact, slq)
    # and the surfaces agree on the argmin (what model selection reads)
    assert jnp.unravel_index(jnp.argmin(exact), exact.shape) == \
        jnp.unravel_index(jnp.argmin(slq), slq.shape)


def test_mle_grid_rejects_unknown_logdet(f64):
    x = jnp.zeros((16, 2), jnp.float64)
    y = jnp.zeros((16,), jnp.float64)
    with pytest.raises(ValueError, match="logdet"):
        gp.mle_grid(x, y, levels=1, rank=4, key=jax.random.PRNGKey(0),
                    sigmas=[1.0], noises=[0.1], logdet="nope")


# ---------------------------------------------------------------------------
# fit_nystrom lambda-scaling regression (dense dual oracle)
# ---------------------------------------------------------------------------

def test_fit_nystrom_matches_explicit_dual_solve(f64):
    """Pins the ridge convention: predict == k(x, Xl) L^{-T} Phi^T
    (Phi Phi^T + lam I)^{-1} y with UNSCALED lam (not lam·n)."""
    key = jax.random.PRNGKey(0)
    n, r = 400, 40
    x = jax.random.normal(key, (n, 5), dtype=jnp.float64)
    y = jnp.sin(x[:, 0])
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-8)
    lam = 0.05
    model = baselines.fit_nystrom(x, y, kernel=ker, lam=lam, rank=r,
                                  key=jax.random.PRNGKey(1))
    lm = model.landmarks
    lo = jnp.linalg.cholesky(ker.gram(lm))
    phi = jax.scipy.linalg.solve_triangular(
        lo, ker.cross(x, lm).T, lower=True).T
    q = jax.random.normal(jax.random.PRNGKey(3), (32, 5), dtype=jnp.float64)

    def dual_pred(ridge):
        alpha = jnp.linalg.solve(phi @ phi.T + ridge * jnp.eye(n), y[:, None])
        beta = jax.scipy.linalg.solve_triangular(
            lo.T, phi.T @ alpha, lower=False)
        return (ker.cross(q, lm) @ beta)[:, 0]

    got = model.predict(q)[:, 0]
    assert float(jnp.max(jnp.abs(got - dual_pred(lam)))) < 1e-10
    # the hedge the old docstring carried: lam·n would be a DIFFERENT fit
    assert float(jnp.max(jnp.abs(got - dual_pred(lam * n)))) > 1e-3
