"""Landmark-policy subsystem: pluggable selection + budgeted adaptive rank.

Property pins for ``repro.landmarks``:

- the uniform policy IS the historical build — ``build_hck(policy="uniform")``
  must equal the no-argument build BITWISE (every pytree leaf);
- every policy sees the SAME tree / permutation / sorted points (policies
  choose rows WITHIN blocks, never the partition);
- budgeted adaptive rank conserves the global budget (sum of per-node
  ranks <= budget), masks are prefix masks, and a budget that pins every
  node to a native rank reproduces that native build up to the documented
  jitter-scaling difference;
- masked models stay exact through the solve/OOS/update engines (the
  identity-padding contract of ``repro.landmarks.budget``);
- the distributed build matches the single-host build per policy at the
  repo's standard 1e-12 f64 gate;
- streaming builds reject non-uniform policies and budgets loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hmatrix, oos, update
from repro.core.hck import (HCKFactors, RankSummary, build_hck,
                            build_hck_streaming, build_sweep_plan,
                            replan_policy, sweep_factors, to_dense)
from repro.core.kernels_fn import BaseKernel
from repro.kernels.registry import (SolveConfig, get_impl, registered,
                                    tile_config)
from repro.landmarks import (KMeansPolicy, LandmarkPolicy, LeveragePolicy,
                             UniformPolicy, allocate_rank_masks,
                             allocate_ranks, get_policy, node_mass,
                             select_indices)

POLICIES = ("uniform", "kmeans", "leverage")


@pytest.fixture(scope="module")
def problem(f64):
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 4), jnp.float64)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-8)
    return x, ker


def _build(x, ker, **kw):
    kw.setdefault("levels", 3)
    kw.setdefault("rank", 16)
    kw.setdefault("key", jax.random.PRNGKey(1))
    return build_hck(x, kernel=ker, **kw)


# ---------------------------------------------------------------------------
# policy registry + protocol
# ---------------------------------------------------------------------------

def test_get_policy_resolution():
    assert isinstance(get_policy(None), UniformPolicy)
    assert isinstance(get_policy("uniform"), UniformPolicy)
    assert isinstance(get_policy("kmeans"), KMeansPolicy)
    assert isinstance(get_policy("leverage"), LeveragePolicy)
    custom = KMeansPolicy(iters=3)
    assert get_policy(custom) is custom
    with pytest.raises(ValueError, match="unknown landmark policy"):
        get_policy("nope")


def test_policies_satisfy_protocol():
    for name in POLICIES:
        p = get_policy(name)
        assert isinstance(p, LandmarkPolicy)
        assert p.name == name


# ---------------------------------------------------------------------------
# uniform policy == historical build, bitwise
# ---------------------------------------------------------------------------

def test_uniform_policy_bitwise_default(problem):
    x, ker = problem
    f0 = _build(x, ker)
    f1 = _build(x, ker, policy="uniform")
    f2 = _build(x, ker, policy=UniformPolicy())
    for fa in (f1, f2):
        for a, b in zip(jax.tree_util.tree_leaves(f0),
                        jax.tree_util.tree_leaves(fa)):
            assert a.dtype == b.dtype and (a == b).all()


def test_policies_share_tree_and_permutation(problem):
    x, ker = problem
    f_uni = _build(x, ker)
    for name in ("kmeans", "leverage"):
        f = _build(x, ker, policy=name)
        assert (np.asarray(f.tree.perm) == np.asarray(f_uni.tree.perm)).all()
        assert (f.x_sorted == f_uni.x_sorted).all()
        # same shapes, different landmark choices
        for a, b in zip(f.landmarks, f_uni.landmarks):
            assert a.shape == b.shape
        assert not all(bool((a == b).all())
                       for a, b in zip(f.landmarks, f_uni.landmarks))


def test_policy_indices_distinct_per_node(f64):
    blocks = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 5),
                               jnp.float64)
    for name in POLICIES:
        idx = select_indices(get_policy(name), jax.random.PRNGKey(4),
                             blocks, 16)
        assert idx.shape == (4, 16)
        assert jnp.issubdtype(idx.dtype, jnp.integer)
        for row in np.asarray(idx):
            assert len(set(row.tolist())) == 16          # distinct
            assert row.min() >= 0 and row.max() < 64


def test_leverage_policy_sigma_independent(f64):
    """Selection must not depend on kernel hyperparameters (the SweepPlan
    policy axis reuses one landmark draw across the (sigma, lam) grid)."""
    blocks = jax.random.normal(jax.random.PRNGKey(5), (2, 64, 3),
                               jnp.float64)
    for name in ("kmeans", "leverage"):
        pol = get_policy(name)
        a = select_indices(pol, jax.random.PRNGKey(6), blocks, 8)
        b = select_indices(pol, jax.random.PRNGKey(6), blocks, 8)
        assert (a == b).all()                            # deterministic


# ---------------------------------------------------------------------------
# strict PD across precisions at the documented jitter floors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision,jitter", [("bf16", 1e-4),
                                              ("f32", 1e-6),
                                              ("f64", 1e-8)])
@pytest.mark.parametrize("policy", POLICIES)
def test_policy_pd_across_precisions(f64, policy, precision, jitter):
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 4), jnp.float64)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=jitter)
    cfg = SolveConfig(precision=precision)
    f = _build(x, ker, policy=policy, config=cfg)
    for cho in f.sigma_cho:
        c = jnp.asarray(cho, jnp.float64)
        assert bool(jnp.isfinite(c).all())
        diag = jnp.diagonal(c, axis1=-2, axis2=-1)
        assert bool((diag > 0).all())                    # strict PD


# ---------------------------------------------------------------------------
# budget allocation
# ---------------------------------------------------------------------------

def test_allocate_ranks_properties():
    masses = jnp.asarray([16.0, 4.0, 1.0, 9.0])
    for budget in (32, 40, 64, 128):
        r = np.asarray(allocate_ranks(masses, budget, 32))
        assert r.sum() <= budget                         # conservation
        assert (r >= 1).all() and (r <= 32).all()
        assert ((r - r.min()) % 8 == 0).all()            # snap-8 extras
    # budget below one slot per node is unsatisfiable
    with pytest.raises(ValueError, match="budget"):
        allocate_ranks(masses, 3, 32)


def test_node_mass_bounds(f64):
    g = jax.random.normal(jax.random.PRNGKey(7), (3, 16, 16), jnp.float64)
    g = g @ jnp.swapaxes(g, -1, -2) + 16 * jnp.eye(16)
    m = np.asarray(node_mass(g))
    assert (m >= 1.0 - 1e-12).all() and (m <= 16.0 + 1e-12).all()


def test_budget_conservation_and_prefix_masks(problem):
    x, ker = problem
    budget = 80
    f = _build(x, ker, rank_budget=budget)
    assert f.rank_mask is not None
    total = 0
    for mask in f.rank_mask:
        m = np.asarray(mask)
        assert set(np.unique(m).tolist()) <= {0.0, 1.0}
        # prefix property: once a row hits 0 it stays 0
        assert (np.diff(m, axis=1) <= 0).all()
        total += int(m.sum())
    s = f.ranks
    assert isinstance(s, RankSummary)
    assert s.total == total <= budget
    assert 1 <= s.min <= s.max <= f.rank
    with pytest.raises(ValueError, match="budget"):
        _build(x, ker, rank_budget=6)                    # < node count (7)


def test_ranks_summary_unbudgeted(problem):
    x, ker = problem
    f = _build(x, ker)
    nodes = sum(1 << lvl for lvl in range(f.levels))
    assert f.rank_mask is None
    assert f.ranks == RankSummary(16, 16, 16 * nodes)


# ---------------------------------------------------------------------------
# budget-masked build == native smaller-rank build (up to jitter scaling)
# ---------------------------------------------------------------------------

def test_budget_masked_matches_native_rank(problem):
    """budget = 8 * nodes pins every node to rank 8; the permutation-
    prefix property makes those 8 landmarks IDENTICAL to a native rank-8
    draw, so the dense operators differ only by the documented jitter
    scaling (jitter * bucket on the gram diagonal): ~1e-6 at 1e-8."""
    x, ker = problem
    f8 = _build(x, ker, rank=8)
    nodes = sum(1 << lvl for lvl in range(3))
    f16 = _build(x, ker, rank=16, rank_budget=8 * nodes)
    assert f16.ranks == RankSummary(8, 8, 8 * nodes)
    for lm16, lm8 in zip(f16.landmarks, f8.landmarks):
        assert (lm16[:, :8, :] == lm8).all()             # prefix landmarks
    err = float(jnp.max(jnp.abs(to_dense(f16) - to_dense(f8))))
    assert err < 1e-5


# ---------------------------------------------------------------------------
# budgeted models through the engines
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def budgeted(problem):
    x, ker = problem
    f = _build(x, ker, rank_budget=80)
    return x, ker, f


def test_budgeted_matvec_vs_dense(budgeted):
    x, ker, f = budgeted
    dense = to_dense(f)
    b = jax.random.normal(jax.random.PRNGKey(8), (256, 3), jnp.float64)
    got = hmatrix.matvec(f, b)
    assert float(jnp.max(jnp.abs(got - dense @ b))) < 1e-10
    assert float(jnp.max(jnp.abs(dense - dense.T))) < 1e-12


def test_budgeted_inverse_vs_dense(budgeted):
    x, ker, f = budgeted
    dense = to_dense(f)
    b = jax.random.normal(jax.random.PRNGKey(9), (256, 2), jnp.float64)
    inv = hmatrix.invert(f, ridge=0.1)
    got = hmatrix.apply_inverse(inv, b)
    want = jnp.linalg.solve(dense + 0.1 * jnp.eye(256), b)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-8
    # logdet picks up log(1) = 0 from the identity padding
    want_ld = 2.0 * jnp.sum(jnp.log(jnp.diagonal(
        jnp.linalg.cholesky(dense + 0.1 * jnp.eye(256)))))
    assert abs(float(hmatrix.logdet(f, ridge=0.1)) - float(want_ld)) < 1e-8


def test_budgeted_invert_multi(budgeted):
    """The stacked-ridge path stays bit-identical to the per-ridge loop
    on masked factors (the grid axis is orthogonal to the prefix masks)."""
    x, ker, f = budgeted
    ridges = jnp.asarray([0.05, 0.5], jnp.float64)
    multi = hmatrix.invert_multi(f, ridges)
    for g, ridge in enumerate([0.05, 0.5]):
        one = hmatrix.invert(f, ridge)
        np.testing.assert_array_equal(np.asarray(multi.linv[g]),
                                      np.asarray(one.linv))
        for a, b in zip(multi.sigma, one.sigma):
            np.testing.assert_array_equal(np.asarray(a[g]), np.asarray(b))
        assert float(multi.logabsdet[g]) == float(one.logabsdet)


def test_budgeted_oos_engines_agree(budgeted):
    x, ker, f = budgeted
    w = jax.random.normal(jax.random.PRNGKey(11), (256,), jnp.float64)
    plan = oos.prepare(f, w)
    q = jax.random.normal(jax.random.PRNGKey(12), (33, 4), jnp.float64)
    batched = oos.apply_plan(f, plan, q, ker)
    walk = oos.apply_plan_walk(f, plan, q, ker)
    assert bool(jnp.isfinite(batched).all())
    assert float(jnp.max(jnp.abs(batched - walk))) < 1e-10


def test_budgeted_insert_downdate_roundtrip(budgeted):
    x, ker, f = budgeted
    x_new = jax.random.normal(jax.random.PRNGKey(13), (5, 4), jnp.float64)
    f2, ys2, rec = update.insert(f, x_new, ker, key=jax.random.PRNGKey(14))
    assert f2.rank_mask is not None
    # inactive U columns stay zeroed on the extended rows
    u_mask = np.repeat(np.asarray(f.rank_mask[-1]), 2, axis=0)
    assert (np.asarray(f2.u)[:, :, :] * (1 - u_mask[:, None, :]) == 0).all()
    f3 = update.downdate(f2, rec.k)
    for a, b in zip(jax.tree_util.tree_leaves(f3),
                    jax.tree_util.tree_leaves(f)):
        assert (a == b).all()                            # bitwise round-trip


def test_budgeted_refit_frozen_preserves_mask(budgeted):
    x, ker, f = budgeted
    f_re = update.refit_frozen(f, ker)
    assert f_re.rank_mask is not None
    for a, b in zip(f_re.rank_mask, f.rank_mask):
        assert (a == b).all()
    u_mask = np.repeat(np.asarray(f.rank_mask[-1]), 2, axis=0)
    assert (np.asarray(f_re.u) * (1 - u_mask[:, None, :]) == 0).all()
    err = float(jnp.max(jnp.abs(to_dense(f_re) - to_dense(f))))
    assert err < 1e-10


# ---------------------------------------------------------------------------
# sweep-plan policy axis + replan
# ---------------------------------------------------------------------------

def test_sweep_policy_axis_matches_direct_build(problem):
    x, ker = problem
    key = jax.random.PRNGKey(1)
    for name in ("kmeans", "leverage"):
        plan = build_sweep_plan(x, levels=3, rank=16, key=key, policy=name)
        f_sw = sweep_factors(plan, ker)
        f_di = _build(x, ker, policy=name)
        assert float(jnp.max(jnp.abs(to_dense(f_sw) - to_dense(f_di)))) == 0.0


def test_replan_policy_matches_fresh_plan(problem):
    x, ker = problem
    key = jax.random.PRNGKey(1)
    plan_u = build_sweep_plan(x, levels=3, rank=16, key=key)
    plan_k = replan_policy(plan_u, rank=16, key=key, policy="kmeans")
    plan_ref = build_sweep_plan(x, levels=3, rank=16, key=key,
                                policy="kmeans")
    for a, b in zip(jax.tree_util.tree_leaves(plan_k),
                    jax.tree_util.tree_leaves(plan_ref)):
        assert (a == b).all()


def test_sweep_factors_budget(problem):
    x, ker = problem
    plan = build_sweep_plan(x, levels=3, rank=16, key=jax.random.PRNGKey(1))
    f = sweep_factors(plan, ker, rank_budget=80)
    assert f.rank_mask is not None and f.ranks.total <= 80


# ---------------------------------------------------------------------------
# streaming guards
# ---------------------------------------------------------------------------

def test_streaming_rejects_non_uniform_policy(problem):
    from repro.data.pipeline import ArraySource
    x, ker = problem
    src = ArraySource(np.asarray(x))
    with pytest.raises(ValueError, match="streaming"):
        build_hck_streaming(src, levels=3, rank=16,
                            key=jax.random.PRNGKey(1), kernel=ker,
                            policy="kmeans")
    with pytest.raises(ValueError, match="streaming"):
        build_hck_streaming(src, levels=3, rank=16,
                            key=jax.random.PRNGKey(1), kernel=ker,
                            rank_budget=80)


# ---------------------------------------------------------------------------
# distributed parity (single-device mesh runs everywhere)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_dist_build_matches_single_host_per_policy(f64, policy):
    from repro.launch.dist_hck import dist_build_hck
    from repro.launch.mesh import kernel_mesh

    mesh = kernel_mesh(1)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 4), jnp.float64)
    key = jax.random.PRNGKey(1)
    f_ref = build_hck(x, levels=4, rank=8, key=key, kernel=ker,
                      policy=policy)
    f_dist = dist_build_hck(x, levels=4, rank=8, key=key, kernel=ker,
                            mesh=mesh, policy=policy)
    for lm_a, lm_b in zip(f_dist.landmarks, f_ref.landmarks):
        assert float(jnp.max(jnp.abs(lm_a - lm_b))) < 1e-12
    diffs = [jnp.max(jnp.abs(f_dist.u - f_ref.u)),
             jnp.max(jnp.abs(f_dist.adiag - f_ref.adiag))]
    for a, b in zip(f_dist.sigma, f_ref.sigma):
        diffs.append(jnp.max(jnp.abs(a - b)))
    assert float(jnp.max(jnp.stack(diffs))) < 1e-12


def test_dist_build_budget_matches_single_host(f64):
    from repro.launch.dist_hck import dist_build_hck
    from repro.launch.mesh import kernel_mesh

    mesh = kernel_mesh(1)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 4), jnp.float64)
    key = jax.random.PRNGKey(1)
    f_ref = build_hck(x, levels=4, rank=8, key=key, kernel=ker,
                      rank_budget=120)
    f_dist = dist_build_hck(x, levels=4, rank=8, key=key, kernel=ker,
                            mesh=mesh, rank_budget=120)
    assert f_dist.rank_mask is not None
    for a, b in zip(f_dist.rank_mask, f_ref.rank_mask):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert float(jnp.max(jnp.abs(f_dist.u - f_ref.u))) < 1e-12


def test_dist_streaming_rejects_non_uniform_policy(f64):
    from repro.data.pipeline import ArraySource
    from repro.launch.dist_hck import dist_build_hck_streaming
    from repro.launch.mesh import kernel_mesh

    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
    src = ArraySource(np.zeros((128, 4)))
    with pytest.raises(ValueError, match="streaming"):
        dist_build_hck_streaming(src, levels=3, rank=8,
                                 key=jax.random.PRNGKey(1), kernel=ker,
                                 mesh=kernel_mesh(1), policy="leverage")


# ---------------------------------------------------------------------------
# policy_dist registry stage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ("l2", "l1"))
def test_policy_dist_stage_parity(f64, metric):
    blocks = jax.random.normal(jax.random.PRNGKey(15), (3, 128, 5),
                               jnp.float64)
    centers = blocks[:, :16, :]
    ref = get_impl("policy_dist", "xla")(blocks, centers, metric=metric)
    pal = get_impl("policy_dist", "pallas")(blocks, centers, metric=metric,
                                            interpret=True)
    assert ref.shape == (3, 128, 16)
    assert float(jnp.max(jnp.abs(jnp.asarray(ref, jnp.float64)
                                 - jnp.asarray(pal, jnp.float64)))) < 1e-5
    if metric == "l2":
        want = jnp.sum((blocks[0, :, None, :] - centers[0, None, :, :]) ** 2,
                       axis=-1)
        assert float(jnp.max(jnp.abs(jnp.asarray(ref[0], jnp.float64)
                                     - want))) < 1e-10


def test_policy_dist_registered_and_tiled():
    assert {b for _, b in registered("policy_dist")} == {"xla", "pallas"}
    t = tile_config("policy_dist", n0=128, r=16, k=1, d=8)
    assert t.block_n0 > 0 and 128 % t.block_n0 == 0
    assert t.vmem_bytes > 0
