"""Serving-path consistency: prefill + step-by-step decode must reproduce
the full-forward logits (same params, exact KV caches) — the strongest
end-to-end check of the cache machinery (rope offsets, cache updates,
length masking, SSM state handoff)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tf
from repro.models.model_zoo import make_prefill_step


def _decode_consistency(arch: str, atol: float = 2e-2):
    cfg = get_arch(arch).reduced()
    if cfg.moe:
        # capacity dropping is batch-shape-dependent (expected MoE
        # production behavior); use generous capacity for exact equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    seq = 32
    toks = jax.random.randint(key, (2, seq), 0, cfg.vocab)
    if cfg.family == "audio":
        toks = jax.random.randint(key, (2, seq, tf.N_CODEBOOKS), 0, cfg.vocab)

    # full forward (teacher): logits at every position
    full_logits, _ = tf.forward(params, cfg, {"tokens": toks}, mode="train",
                                remat=False)

    # prefill on the first half, then decode one token at a time
    half = seq // 2
    pre = {"tokens": toks[:, :half]}
    logits_pre, layer_caches = make_prefill_step(cfg)(params, pre)
    caches = tf.init_decode_caches(cfg, 2, seq, hck=False, abstract=False)
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        k, v = layer_caches[0], layer_caches[1]
        caches["k"] = caches["k"].at[:, :, :, :half].set(k)
        caches["v"] = caches["v"].at[:, :, :, :half].set(v)
    if cfg.ssm:
        caches["ssm"] = layer_caches[0]
        caches["conv"] = layer_caches[1]
        if cfg.family == "hybrid" and len(layer_caches) > 2:
            every = cfg.shared_attn_every
            napp = caches["shared_k"].shape[0]
            idx = jnp.arange(napp) * every
            caches["shared_k"] = caches["shared_k"].at[:, :, :, :half].set(
                layer_caches[2][idx])
            caches["shared_v"] = caches["shared_v"].at[:, :, :, :half].set(
                layer_caches[3][idx])

    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1], np.float32),
        np.asarray(full_logits[:, half - 1], np.float32), atol=atol,
        rtol=atol)

    for pos in range(half, seq):
        step_tok = toks[:, pos:pos + 1]
        logits, caches = tf.decode_step(
            params, cfg, caches, {"tokens": step_tok},
            jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, pos], np.float32), atol=atol,
            rtol=atol)


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen3-32b",
                                  "mixtral-8x22b", "musicgen-medium"])
def test_decode_matches_full_forward_attention(arch):
    _decode_consistency(arch)


def test_decode_matches_full_forward_ssm():
    _decode_consistency("mamba2-780m")


def test_decode_matches_full_forward_hybrid():
    # zamba2's reduced config uses the hck backend; force exact attention so
    # the teacher comparison is exact (hck decode has its own agreement test)
    cfg = get_arch("zamba2-7b")
    import repro.configs.base as base

    exact_cfg = dataclasses.replace(cfg, attn_backend="full")
    base._ARCHS["zamba2-exact-test"] = lambda: exact_cfg
    try:
        _decode_consistency("zamba2-exact-test")
    finally:
        del base._ARCHS["zamba2-exact-test"]


@pytest.mark.parametrize("arch", ["granite-3-2b", "zamba2-7b"])
def test_serve_session_end_to_end(arch):
    """ServeSession prefill -> decode produces finite tokens (covers the
    cache-absorption plumbing incl. learned-landmark decode states)."""
    from repro.models.model_zoo import input_specs
    from repro.configs.base import ShapeConfig
    from repro.serving.serve_loop import ServeSession

    cfg = get_arch(arch).reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("serve", 32, 2, "prefill")
    batch = input_specs(cfg, shape, abstract=False, key=jax.random.PRNGKey(1))
    sess = ServeSession(cfg, params, max_seq=64)
    last = sess.prefill(batch)
    assert bool(jnp.all(jnp.isfinite(last)))
    nxt = jnp.argmax(last, axis=-1)[:, None]
    if cfg.family == "audio":
        nxt = nxt[..., None].repeat(tf.N_CODEBOOKS, -1)
    out = sess.decode(nxt, steps=3)
    assert out.shape[1] == 4


def test_serve_session_caches_compiled_decode_step():
    """decode() must build the jitted step once per session — re-wrapping
    make_decode_step in jax.jit on every call retraced the whole model per
    generation request."""
    from repro.models.model_zoo import input_specs
    from repro.configs.base import ShapeConfig
    from repro.serving.serve_loop import ServeSession

    cfg = get_arch("granite-3-2b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("serve", 16, 1, "prefill")
    batch = input_specs(cfg, shape, abstract=False, key=jax.random.PRNGKey(1))
    sess = ServeSession(cfg, params, max_seq=32)
    last = sess.prefill(batch)
    nxt = jnp.argmax(last, axis=-1)[:, None]
    assert sess._decode_fn is None
    sess.decode(nxt, steps=1)
    fn = sess._decode_fn
    assert fn is not None
    sess.decode(nxt, steps=1)
    assert sess._decode_fn is fn
