"""Serving-path consistency: prefill + step-by-step decode must reproduce
the full-forward logits (same params, exact KV caches) — the strongest
end-to-end check of the cache machinery (rope offsets, cache updates,
length masking, SSM state handoff).

The KRR half (bottom) pins the versioned hot-swap registry: a publish
concurrent with a request stream flips responses atomically from one
version to the next (never a mixed response), and a rollback re-points
at the STORED engine, so its predictions are bitwise identical."""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tf
from repro.models.model_zoo import make_prefill_step


def _decode_consistency(arch: str, atol: float = 2e-2):
    cfg = get_arch(arch).reduced()
    if cfg.moe:
        # capacity dropping is batch-shape-dependent (expected MoE
        # production behavior); use generous capacity for exact equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    seq = 32
    toks = jax.random.randint(key, (2, seq), 0, cfg.vocab)
    if cfg.family == "audio":
        toks = jax.random.randint(key, (2, seq, tf.N_CODEBOOKS), 0, cfg.vocab)

    # full forward (teacher): logits at every position
    full_logits, _ = tf.forward(params, cfg, {"tokens": toks}, mode="train",
                                remat=False)

    # prefill on the first half, then decode one token at a time
    half = seq // 2
    pre = {"tokens": toks[:, :half]}
    logits_pre, layer_caches = make_prefill_step(cfg)(params, pre)
    caches = tf.init_decode_caches(cfg, 2, seq, hck=False, abstract=False)
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        k, v = layer_caches[0], layer_caches[1]
        caches["k"] = caches["k"].at[:, :, :, :half].set(k)
        caches["v"] = caches["v"].at[:, :, :, :half].set(v)
    if cfg.ssm:
        caches["ssm"] = layer_caches[0]
        caches["conv"] = layer_caches[1]
        if cfg.family == "hybrid" and len(layer_caches) > 2:
            every = cfg.shared_attn_every
            napp = caches["shared_k"].shape[0]
            idx = jnp.arange(napp) * every
            caches["shared_k"] = caches["shared_k"].at[:, :, :, :half].set(
                layer_caches[2][idx])
            caches["shared_v"] = caches["shared_v"].at[:, :, :, :half].set(
                layer_caches[3][idx])

    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1], np.float32),
        np.asarray(full_logits[:, half - 1], np.float32), atol=atol,
        rtol=atol)

    for pos in range(half, seq):
        step_tok = toks[:, pos:pos + 1]
        logits, caches = tf.decode_step(
            params, cfg, caches, {"tokens": step_tok},
            jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, pos], np.float32), atol=atol,
            rtol=atol)


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen3-32b",
                                  "mixtral-8x22b", "musicgen-medium"])
def test_decode_matches_full_forward_attention(arch):
    _decode_consistency(arch)


def test_decode_matches_full_forward_ssm():
    _decode_consistency("mamba2-780m")


def test_decode_matches_full_forward_hybrid():
    # zamba2's reduced config uses the hck backend; force exact attention so
    # the teacher comparison is exact (hck decode has its own agreement test)
    cfg = get_arch("zamba2-7b")
    import repro.configs.base as base

    exact_cfg = dataclasses.replace(cfg, attn_backend="full")
    base._ARCHS["zamba2-exact-test"] = lambda: exact_cfg
    try:
        _decode_consistency("zamba2-exact-test")
    finally:
        del base._ARCHS["zamba2-exact-test"]


@pytest.mark.parametrize("arch", ["granite-3-2b", "zamba2-7b"])
def test_serve_session_end_to_end(arch):
    """ServeSession prefill -> decode produces finite tokens (covers the
    cache-absorption plumbing incl. learned-landmark decode states)."""
    from repro.models.model_zoo import input_specs
    from repro.configs.base import ShapeConfig
    from repro.serving.serve_loop import ServeSession

    cfg = get_arch(arch).reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("serve", 32, 2, "prefill")
    batch = input_specs(cfg, shape, abstract=False, key=jax.random.PRNGKey(1))
    sess = ServeSession(cfg, params, max_seq=64)
    last = sess.prefill(batch)
    assert bool(jnp.all(jnp.isfinite(last)))
    nxt = jnp.argmax(last, axis=-1)[:, None]
    if cfg.family == "audio":
        nxt = nxt[..., None].repeat(tf.N_CODEBOOKS, -1)
    out = sess.decode(nxt, steps=3)
    assert out.shape[1] == 4


def test_serve_session_caches_compiled_decode_step():
    """decode() must build the jitted step once per session — re-wrapping
    make_decode_step in jax.jit on every call retraced the whole model per
    generation request."""
    from repro.models.model_zoo import input_specs
    from repro.configs.base import ShapeConfig
    from repro.serving.serve_loop import ServeSession

    cfg = get_arch("granite-3-2b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("serve", 16, 1, "prefill")
    batch = input_specs(cfg, shape, abstract=False, key=jax.random.PRNGKey(1))
    sess = ServeSession(cfg, params, max_seq=32)
    last = sess.prefill(batch)
    nxt = jnp.argmax(last, axis=-1)[:, None]
    assert sess._decode_fn is None
    sess.decode(nxt, steps=1)
    fn = sess._decode_fn
    assert fn is not None
    sess.decode(nxt, steps=1)
    assert sess._decode_fn is fn


# ---------------------------------------------------------------------------
# KRR model registry: versioned hot swap / rollback / mesh parity
# ---------------------------------------------------------------------------

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _tgt(x):
    return jnp.sin(x[:, 0]) + 0.25 * jnp.cos(2.0 * x[:, 1])


@pytest.fixture(scope="module")
def krr_model(f64):
    from repro.core import krr
    from repro.core.kernels_fn import BaseKernel

    x = jax.random.normal(jax.random.PRNGKey(0), (256, 5),
                          dtype=jnp.float64)
    model = krr.fit(x, _tgt(x), kernel=BaseKernel("gaussian", sigma=2.0,
                                                  jitter=1e-8),
                    lam=1e-2, rank=16, leaf_size=32, levels=3,
                    key=jax.random.PRNGKey(1))
    return model


def _update_batch(seed=5, q=16, d=5):
    x_new = jax.random.normal(jax.random.PRNGKey(seed), (q, d),
                              dtype=jnp.float64)
    return x_new, _tgt(x_new)


def test_registry_hot_swap_under_load(krr_model):
    """A serving thread drains micro-batches while the main thread runs
    an online update + publish.  Every response must come from exactly
    ONE version (recomputing its batch on the stamped version's stored
    engine is bitwise equal) and versions flip monotonically 1 -> 2 —
    the atomic-snapshot contract of ModelRegistry.predict."""
    from repro.serving.predict_service import ModelRegistry
    from repro.serving.serve_loop import KRRServeLoop

    registry = ModelRegistry(krr_model, tag="fit", warmup=True)
    loop = KRRServeLoop(registry)
    queries = jax.random.normal(jax.random.PRNGKey(2), (512, 5),
                                dtype=jnp.float64)
    batches = [queries[i:i + 16] for i in range(0, 512, 16)]
    served: list = []       # (batch_index, ServedBatch)
    stop = threading.Event()

    def worker():
        i = 0
        while not stop.is_set():
            served.append((i % len(batches),
                           loop.serve(batches[i % len(batches)])))
            i += 1

    t = threading.Thread(target=worker)
    t.start()
    try:
        deadline = time.monotonic() + 60
        while len(served) < 5 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert len(served) >= 5, "serving thread made no progress"
        xu, yu = _update_batch()
        v2, info = registry.update_and_publish(xu, yu, tag="update",
                                               warmup=True)
        assert v2 == 2 and info.record.k > 0
        while (not any(r.version == v2 for _, r in served)
               and time.monotonic() < deadline):
            time.sleep(0.005)
    finally:
        stop.set()
        t.join(timeout=60)
    assert not t.is_alive()

    versions = [r.version for _, r in served]
    assert set(versions) <= {1, 2}
    assert versions[0] == 1 and versions[-1] == 2
    # monotone flip: once v2 serves, v1 never serves again
    assert versions == sorted(versions)
    assert loop.versions_served == [1, 2]
    # no mixed responses: each response equals a full recompute on the
    # stored engine of the version it was stamped with, BITWISE
    checked = set()
    for bi, r in served:
        if (bi, r.version) in checked:
            continue
        checked.add((bi, r.version))
        z_ref = registry.get(r.version).engine(batches[bi])
        np.testing.assert_array_equal(np.asarray(r.z), np.asarray(z_ref))
    # both versions actually got the recompute treatment
    assert {v for _, v in checked} == {1, 2}


def test_registry_rollback_is_bitwise_identical(krr_model):
    """Rolling back re-points at the STORED entry — same engine object,
    same factor arrays — so post-rollback predictions are bitwise equal
    to what v1 served before the swap."""
    from repro.serving.predict_service import ModelRegistry

    registry = ModelRegistry(krr_model, tag="fit")
    queries = jax.random.normal(jax.random.PRNGKey(3), (64, 5),
                                dtype=jnp.float64)
    z1, v1 = registry.predict(queries)
    assert v1 == 1

    xu, yu = _update_batch(seed=7)
    v2, _ = registry.update_and_publish(xu, yu, tag="update")
    z2, v = registry.predict(queries)
    assert v == v2 == 2
    assert not np.array_equal(np.asarray(z1), np.asarray(z2))

    back = registry.rollback()            # default: previous version
    assert back == 1 and registry.live_version == 1
    z3, v = registry.predict(queries)
    assert v == 1
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z3))
    assert registry.stats["swaps"] == 3
    # the live version cannot be retired; a stored one can
    with pytest.raises(ValueError, match="live"):
        registry.retire(1)
    registry.retire(2)
    assert registry.versions() == [1]


@needs_mesh
def test_mesh_registry_swap_parity(krr_model):
    """The distributed registry (MeshPredictEngine per version) serves the
    same values as the single-host one through a hot swap — the 8-device
    lane's swap-parity gate."""
    from repro.serving.predict_service import ModelRegistry

    mesh = jax.make_mesh((8,), ("dev",))
    host = ModelRegistry(krr_model, tag="fit")
    dist = ModelRegistry(krr_model, tag="fit", mesh=mesh, warmup=False)
    queries = jax.random.normal(jax.random.PRNGKey(4), (96, 5),
                                dtype=jnp.float64)
    z_h, _ = host.predict(queries)
    z_d, v = dist.predict(queries)
    assert v == 1
    np.testing.assert_allclose(np.asarray(z_d), np.asarray(z_h),
                               rtol=1e-6, atol=1e-6)

    xu, yu = _update_batch(seed=11)
    host.update_and_publish(xu, yu, key=jax.random.PRNGKey(12))
    dist.update_and_publish(xu, yu, key=jax.random.PRNGKey(12))
    z_h, _ = host.predict(queries)
    z_d, v = dist.predict(queries)
    assert v == 2
    np.testing.assert_allclose(np.asarray(z_d), np.asarray(z_h),
                               rtol=1e-6, atol=1e-6)
    # rollback parity too: both registries re-point at their stored v1
    host.rollback(1)
    dist.rollback(1)
    z_h, _ = host.predict(queries)
    z_d, v = dist.predict(queries)
    assert v == 1
    np.testing.assert_allclose(np.asarray(z_d), np.asarray(z_h),
                               rtol=1e-6, atol=1e-6)
