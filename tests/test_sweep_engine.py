"""Hyperparameter sweep engine: σ-axis reuse, λ-axis batching, API layer.

Oracles: ``build_hck`` (the sweep's distance-cached factors must reproduce
a fresh per-σ build under the same key), a Python loop of ``invert`` (the
multi-ridge inversion must reproduce it per grid point), and the dense
``slogdet``/``cho_solve`` paths (f64, Algorithm-2 grade).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gp, hmatrix, krr
from repro.core.hck import (build_hck, build_sweep_plan, sweep_factors,
                            to_dense)
from repro.core.kernels_fn import BaseKernel
from repro.kernels.registry import SolveConfig

RIDGES = [1e-3, 1e-2, 1e-1, 1.0]


def _factors_equal(fa, fb, atol):
    np.testing.assert_array_equal(np.asarray(fa.x_sorted),
                                  np.asarray(fb.x_sorted))
    np.testing.assert_allclose(np.asarray(fa.adiag), np.asarray(fb.adiag),
                               atol=atol)
    np.testing.assert_allclose(np.asarray(fa.u), np.asarray(fb.u), atol=atol)
    for name in ("sigma", "sigma_cho", "w"):
        for a, b in zip(getattr(fa, name), getattr(fb, name)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=atol)


# ---------------------------------------------------------------------------
# σ-axis: distance-cached factor instantiation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("name", ["gaussian", "laplace", "imq"])
def test_sweep_factors_match_build_hck(f64, backend, name):
    """One plan serves every bandwidth: sweep_factors(plan, k_sigma) must
    reproduce build_hck(x, kernel=k_sigma) under the shared key for every
    supported base kernel and backend."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 5), dtype=jnp.float64)
    key = jax.random.PRNGKey(1)
    cfg = SolveConfig(backend=backend)
    plan = build_sweep_plan(x, levels=3, rank=8, key=key, name=name)
    for sigma in (0.7, 2.0):
        ker = BaseKernel(name, sigma=sigma, jitter=1e-8)
        f_sweep = sweep_factors(plan, ker, cfg)
        f_ref = build_hck(x, levels=3, rank=8, key=key, kernel=ker,
                          config=cfg)
        _factors_equal(f_sweep, f_ref, atol=1e-10)


def test_sweep_plan_rejects_metric_mismatch(f64):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 3), dtype=jnp.float64)
    plan = build_sweep_plan(x, levels=2, rank=8, key=jax.random.PRNGKey(1),
                            name="gaussian")
    with pytest.raises(ValueError, match="metric"):
        sweep_factors(plan, BaseKernel("laplace", sigma=1.0))


def test_sweep_plan_rejects_unsweepable_kernel(f64):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 3), dtype=jnp.float64)
    with pytest.raises(ValueError, match="metric"):
        build_sweep_plan(x, levels=2, rank=8, key=jax.random.PRNGKey(1),
                         name="matern")


def test_sweep_factors_shared_landmarks(f64):
    """§4.2 shared-landmark (flat compositional) builds sweep too."""
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 3), dtype=jnp.float64)
    key = jax.random.PRNGKey(3)
    plan = build_sweep_plan(x, levels=2, rank=8, key=key,
                            shared_landmarks=True)
    ker = BaseKernel("gaussian", sigma=1.3, jitter=1e-8)
    f_sweep = sweep_factors(plan, ker)
    f_ref = build_hck(x, levels=2, rank=8, key=key, kernel=ker,
                      shared_landmarks=True)
    _factors_equal(f_sweep, f_ref, atol=1e-10)


# ---------------------------------------------------------------------------
# λ-axis: multi-ridge inversion and the logdet byproduct
# ---------------------------------------------------------------------------

def test_logdet_matches_dense_slogdet_over_ridge_grid(small_problem):
    """Structured logdet == dense slogdet oracle across a ridge grid (f64),
    through the SolveConfig-threaded signature."""
    _, _, f = small_problem
    a = to_dense(f)
    eye = jnp.eye(f.n, dtype=a.dtype)
    cfg = SolveConfig(backend="xla")
    for ridge in RIDGES:
        got = float(hmatrix.logdet(f, ridge=ridge, config=cfg))
        _, want = jnp.linalg.slogdet(a + ridge * eye)
        assert abs(got - float(want)) < 1e-8 * max(1.0, abs(float(want)))


def test_invert_multi_bit_matches_invert_loop(small_problem):
    """invert_multi(ridges)[g] reproduces invert(ridges[g]) exactly: the
    stacked leaf_factor launch and the per-ridge tail run the same ops on
    the same blocks, so the grid axis must introduce no drift at all."""
    _, _, f = small_problem
    ridges = jnp.asarray(RIDGES, dtype=jnp.float64)
    multi = hmatrix.invert_multi(f, ridges)
    for g, ridge in enumerate(RIDGES):
        one = hmatrix.invert(f, ridge)
        np.testing.assert_array_equal(np.asarray(multi.adiag[g]),
                                      np.asarray(one.adiag))
        np.testing.assert_array_equal(np.asarray(multi.u[g]),
                                      np.asarray(one.u))
        np.testing.assert_array_equal(np.asarray(multi.linv[g]),
                                      np.asarray(one.linv))
        for a, b in zip(multi.sigma, one.sigma):
            np.testing.assert_array_equal(np.asarray(a[g]), np.asarray(b))
        for a, b in zip(multi.w, one.w):
            np.testing.assert_array_equal(np.asarray(a[g]), np.asarray(b))
        assert float(multi.logabsdet[g]) == float(one.logabsdet)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_invert_multi_solves_against_dense(small_problem, backend):
    """Every grid point's inverse actually inverts: (K + λI) x == b against
    the dense oracle, on both leaf_factor backends."""
    _, _, f = small_problem
    cfg = SolveConfig(backend=backend)
    a = to_dense(f)
    b = jax.random.normal(jax.random.PRNGKey(7), (f.n,), dtype=jnp.float64)
    ridges = jnp.asarray(RIDGES, dtype=jnp.float64)
    invs = hmatrix.invert_multi(f, ridges, cfg)
    for g, ridge in enumerate(RIDGES):
        inv_g = jax.tree_util.tree_map(lambda x, g=g: x[g], invs)
        x = hmatrix.apply_inverse(inv_g, b, cfg)
        want = jnp.linalg.solve(a + ridge * jnp.eye(f.n, dtype=a.dtype), b)
        resid = float(jnp.linalg.norm(x - want) / jnp.linalg.norm(want))
        assert resid < 1e-6, (backend, ridge, resid)


def test_invert_multi_levels_zero(f64):
    """The dense 0-level degenerate case batches over ridges too."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 3), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=1.0, jitter=1e-8)
    f = build_hck(x, levels=0, rank=0, key=jax.random.PRNGKey(1), kernel=ker)
    ridges = jnp.asarray(RIDGES, dtype=jnp.float64)
    multi = hmatrix.invert_multi(f, ridges)
    for g, ridge in enumerate(RIDGES):
        one = hmatrix.invert(f, ridge)
        np.testing.assert_allclose(np.asarray(multi.adiag[g]),
                                   np.asarray(one.adiag), atol=1e-12)
        assert abs(float(multi.logabsdet[g] - one.logabsdet)) < 1e-10


def test_invert_multi_rejects_non_1d(small_problem):
    _, _, f = small_problem
    with pytest.raises(ValueError, match="1-D"):
        hmatrix.invert_multi(f, jnp.ones((2, 2), dtype=jnp.float64))


# ---------------------------------------------------------------------------
# API layer: fit_path, mle_grid, mle_objective
# ---------------------------------------------------------------------------

def test_fit_path_matches_per_lambda_fits(f64):
    """The regularization path reproduces fit() per λ and scores every λ
    on the validation set in one OOS pass."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 4), dtype=jnp.float64)
    y = jnp.sin(x[:, 0]) + 0.25 * jnp.cos(2.0 * x[:, 1])
    xv = jax.random.normal(jax.random.PRNGKey(9), (64, 4), dtype=jnp.float64)
    yv = jnp.sin(xv[:, 0]) + 0.25 * jnp.cos(2.0 * xv[:, 1])
    ker = BaseKernel("gaussian", sigma=1.5)
    key = jax.random.PRNGKey(5)
    lams = [1e-3, 1e-1]
    path = krr.fit_path(x, y, kernel=ker, lams=lams, rank=16, key=key,
                        x_val=xv, y_val=yv)
    assert path.scores.shape == (2,)
    for g, lam in enumerate(lams):
        m = krr.fit(x, y, kernel=ker, lam=lam, rank=16, key=key)
        np.testing.assert_allclose(np.asarray(path.alphas[g]),
                                   np.asarray(m.alpha), atol=1e-9)
        pred_path = path.model(g).predict(xv)
        pred_fit = m.predict(xv)
        np.testing.assert_allclose(np.asarray(pred_path),
                                   np.asarray(pred_fit), atol=1e-9)
        score = float(krr.relative_error(pred_fit, yv))
        assert abs(score - float(path.scores[g])) < 1e-9
    assert float(jnp.min(path.scores)) == pytest.approx(
        float(krr.relative_error(path.best().predict(xv), yv)), abs=1e-12)


def test_fit_path_without_validation(f64):
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 3), dtype=jnp.float64)
    y = jnp.sin(x[:, 0])
    path = krr.fit_path(x, y, kernel=BaseKernel("gaussian", sigma=1.0),
                        lams=[1e-2, 1e-1], rank=8, key=jax.random.PRNGKey(1))
    assert path.scores is None
    with pytest.raises(ValueError, match="validation"):
        path.best()
    assert path.model(0).predict(x[:16]).shape == (16,)


def test_mle_grid_matches_mle_objective(f64):
    """The σ×λ surface matches the σ-folded per-point objective (the
    argsort scale-invariance + distance-cache path is exact)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 3), dtype=jnp.float64)
    y = jnp.sin(x[:, 0])
    key = jax.random.PRNGKey(5)
    sigmas, noises = [0.8, 1.6], jnp.asarray([1e-2, 1e-1], dtype=jnp.float64)
    surf = gp.mle_grid(x, y, levels=2, rank=8, key=key, sigmas=sigmas,
                       noises=noises)
    assert surf.shape == (2, 2)
    nll = gp.mle_objective(x, y, levels=2, rank=8, key=key)
    for i, s in enumerate(sigmas):
        for j in range(noises.shape[0]):
            want = float(nll(jnp.log(s), jnp.log(noises[j])))
            assert float(surf[i, j]) == pytest.approx(want, rel=1e-9,
                                                      abs=1e-8)


def test_mle_objective_honors_kernel_name(f64):
    """Regression for the satellite bugfix: `name` used to be ignored and
    `gaussian` hard-coded; laplace must now produce a different surface."""
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 3), dtype=jnp.float64)
    y = jnp.sin(x[:, 0])
    key = jax.random.PRNGKey(5)
    nll_g = gp.mle_objective(x, y, levels=2, rank=8, key=key,
                             name="gaussian")
    nll_l = gp.mle_objective(x, y, levels=2, rank=8, key=key, name="laplace")
    a = float(nll_g(jnp.log(1.0), jnp.log(0.1)))
    b = float(nll_l(jnp.log(1.0), jnp.log(0.1)))
    assert a != b
    # the laplace surface must agree with a direct laplace fit NLL
    ker = BaseKernel("laplace", sigma=1.0)
    f = build_hck(x, levels=2, rank=8, key=key, kernel=ker)
    y_sorted = y[f.tree.perm][:, None]
    inv = hmatrix.invert(f, 0.1)
    alpha = hmatrix.apply_inverse(inv, y_sorted)
    want = float(0.5 * jnp.sum(y_sorted[:, 0] * alpha[:, 0])
                 + 0.5 * inv.logabsdet
                 + 0.5 * x.shape[0] * jnp.log(2 * jnp.pi))
    assert b == pytest.approx(want, rel=1e-9)


def test_mle_objective_rejects_unfoldable_kernel(f64):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 3), dtype=jnp.float64)
    with pytest.raises(ValueError, match="foldable"):
        gp.mle_objective(x, x[:, 0], levels=2, rank=8,
                         key=jax.random.PRNGKey(1), name="matern")
