"""Mixed-precision policies vs the f64 oracle, per the SolveConfig contract.

Gates the documented bounds (registry.SolveConfig.precision docstring) at
test scale: Gram-family factors element-wise (<= 2e-2 bf16 / 1e-4 f32),
matvec + OOS predictions operator-level (<= 5e-2 bf16 / 1e-4 f32), the
bf16 inversion ridge floor (ridge >~ n0 * eps_bf16), and the interpret
auto-detection / compiled-mode contract satellites.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import hmatrix, oos
from repro.core.hck import build_hck
from repro.core.kernels_fn import BaseKernel
from repro.kernels.registry import (PRECISIONS, SolveConfig,
                                    accelerator_present, precision_policy)

#: (factor tol, operator tol) — the documented bounds vs the f64 oracle
TOLS = {"f32": (1e-4, 1e-4), "bf16": (2e-2, 5e-2)}


def _rel(a, b):
    b = jnp.asarray(b, jnp.float64)
    return float(jnp.linalg.norm(jnp.asarray(a, jnp.float64) - b)
                 / jnp.linalg.norm(b))


@pytest.fixture(scope="module")
def mp_problem(f64):
    """256-point f64 problem with the jitter the precision gates assume."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 5), jnp.float64)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-4)
    f64_fac = build_hck(x, levels=3, rank=16, key=jax.random.PRNGKey(1),
                        kernel=ker)
    b = jax.random.normal(jax.random.PRNGKey(2), (256, 2), jnp.float64)
    return x, ker, f64_fac, b


# ---------------------------------------------------------------------------
# policy plumbing
# ---------------------------------------------------------------------------

def test_precision_policy_mapping():
    assert precision_policy(None) is None
    assert precision_policy(SolveConfig()) is None
    assert precision_policy(SolveConfig(precision="bf16")) == (
        jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32))
    assert precision_policy(SolveConfig(precision="f32")) == (
        jnp.dtype(jnp.float32), jnp.dtype(jnp.float32))
    assert precision_policy(SolveConfig(precision="f64")) == (
        jnp.dtype(jnp.float64), jnp.dtype(jnp.float64))


def test_invalid_precision_rejected():
    with pytest.raises(ValueError, match="precision"):
        SolveConfig(precision="fp16")
    assert set(TOLS) < set(PRECISIONS) | {"f64"}


def test_interpret_auto_detection():
    # default None resolves to a concrete bool at construction (hashable
    # static jit arg): interpret exactly when no accelerator is attached
    cfg = SolveConfig()
    assert cfg.interpret is (not accelerator_present())
    # explicit values are always honored
    assert SolveConfig(interpret=True).interpret is True
    assert SolveConfig(interpret=False).interpret is False


def test_compiled_mode_xla_smoke(mp_problem):
    # the compiled-path contract: interpret=False must be constructible and
    # runnable everywhere — on CPU the xla backend simply ignores it
    x, ker, f_ref, b = mp_problem
    cfg = SolveConfig(backend="xla", interpret=False)
    f = build_hck(x, levels=3, rank=16, key=jax.random.PRNGKey(1),
                  kernel=ker, config=cfg)
    assert _rel(hmatrix.matvec(f, b, cfg), hmatrix.matvec(f_ref, b)) < 1e-12


# ---------------------------------------------------------------------------
# build + matvec bounds vs the f64 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prec", ["f32", "bf16"])
def test_build_precision_bounds(mp_problem, prec):
    x, ker, f_ref, b = mp_problem
    ftol, otol = TOLS[prec]
    cfg = SolveConfig(precision=prec)
    f = build_hck(x, levels=3, rank=16, key=jax.random.PRNGKey(1),
                  kernel=ker, config=cfg)

    # tree construction precedes the cast: same shapes leaf-for-leaf
    assert f.adiag.shape == f_ref.adiag.shape

    # Gram-family factors gate element-wise
    factor_err = max(
        [_rel(f.adiag, f_ref.adiag)]
        + [_rel(a, b_) for a, b_ in zip(f.sigma, f_ref.sigma)]
        + [_rel(a, b_) for a, b_ in zip(f.sigma_cho, f_ref.sigma_cho)])
    assert factor_err <= ftol, f"{prec} factors: {factor_err:.2e} > {ftol}"

    # the Sigma^{-1}-projected bases gate operator-level (matvec)
    matvec_err = _rel(hmatrix.matvec(f, b.astype(f.u.dtype)),
                      hmatrix.matvec(f_ref, b))
    assert matvec_err <= otol, f"{prec} matvec: {matvec_err:.2e} > {otol}"


@pytest.mark.parametrize("prec", ["f32", "bf16"])
def test_predict_precision_bounds(mp_problem, prec):
    # f64 factors + mixed-precision apply: the serving-side policy
    x, ker, f_ref, b = mp_problem
    _, otol = TOLS[prec]
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 2), jnp.float64)
    q = jax.random.normal(jax.random.PRNGKey(4), (64, 5), jnp.float64)
    want = oos.predict(f_ref, w, q, ker)
    got = oos.predict(f_ref, w, q, ker, SolveConfig(precision=prec))
    err = _rel(got, want)
    assert err <= otol, f"{prec} predict: {err:.2e} > {otol}"


# ---------------------------------------------------------------------------
# inversion: the bf16 ridge floor
# ---------------------------------------------------------------------------

def test_inversion_ridge_floor(mp_problem):
    x, ker, f_ref, b = mp_problem

    # f32 builds invert at any ridge the f64 oracle tolerates
    f32f = build_hck(x, levels=3, rank=16, key=jax.random.PRNGKey(1),
                     kernel=ker, config=SolveConfig(precision="f32"))
    z32 = hmatrix.solve(f32f, b.astype(f32f.u.dtype), ridge=1e-2)
    z64 = hmatrix.solve(f_ref, b, ridge=1e-2)
    assert bool(jnp.all(jnp.isfinite(z32)))
    assert _rel(z32, z64) <= 5e-3

    # bf16-built factors need ridge >~ n0 * eps_bf16 (~1e-1 at n0=32):
    # below it the leaf Schur complement can go indefinite (NaN Cholesky),
    # so the contract only promises finiteness at the documented floor
    fbf = build_hck(x, levels=3, rank=16, key=jax.random.PRNGKey(1),
                    kernel=ker, config=SolveConfig(precision="bf16"))
    zbf = hmatrix.solve(fbf, b.astype(fbf.u.dtype), ridge=1e-1)
    assert bool(jnp.all(jnp.isfinite(zbf)))
    # inverse application amplifies the 5e-2 forward bound by kappa, so
    # the solve is gated an octave looser than matvec/predict
    assert _rel(zbf, hmatrix.solve(f_ref, b, ridge=1e-1)) <= 1e-1
