"""MoE dispatch and SSD scan against direct references (+ hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.moe import moe_ffn
from repro.models.ssm import (causal_conv1d, ssd_chunked, ssd_decode_step,
                              ssd_reference)

SETTINGS = dict(max_examples=6, deadline=None)


@given(seed=st.integers(0, 2**31 - 1),
       chunk=st.sampled_from([8, 16, 32]),
       heads=st.sampled_from([2, 4]))
@settings(**SETTINGS)
def test_ssd_chunked_equals_recurrence(seed, chunk, heads):
    B, S, P, G, N = 2, 64, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, heads, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, heads)))
    a = -jnp.exp(jax.random.normal(ks[2], (heads,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    got = ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
    want = ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-5)


def test_ssd_decode_continues_prefill():
    """Recurrent decode from the final prefill state matches running the
    full chunked scan over the extended sequence."""
    B, S, H, P, G, N = 1, 32, 2, 4, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S + 1, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 1, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, S + 1, G, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, S + 1, G, N)) * 0.3
    full = ssd_reference(x, dt, a, bm, cm)
    # prefill state after S steps
    state = jnp.zeros((B, H, N, P))
    for t in range(S):
        state, _ = ssd_decode_step(state, x[:, t], dt[:, t], a, bm[:, t],
                                   cm[:, t])
    state, y = ssd_decode_step(state, x[:, S], dt[:, S], a, bm[:, S],
                               cm[:, S])
    np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, S]),
                               rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([2, 3, 4]))
@settings(**SETTINGS)
def test_conv_decode_equals_full(seed, k):
    B, S, C = 2, 16, 6
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, S, C))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, C))
    full, _ = causal_conv1d(x, w)
    _, cache = causal_conv1d(x[:, :-1], w)
    last, _ = causal_conv1d(x[:, -1:], w, cache=cache)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-5, atol=1e-6)


def test_moe_generous_capacity_matches_dense():
    d, E, ff, K = 16, 4, 32, 2
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (2, 8, d))
    rw = jax.random.normal(ks[1], (d, E)) * 0.1
    wg = jax.random.normal(ks[2], (E, d, ff)) * 0.1
    wu = jax.random.normal(ks[3], (E, d, ff)) * 0.1
    wd = jax.random.normal(ks[4], (E, ff, d)) * 0.1
    y, aux = moe_ffn(x, rw, wg, wu, wd, top_k=K, capacity_factor=float(E))
    # dense reference
    n = 16
    xt = x.reshape(n, d)
    pr = jax.nn.softmax(xt @ rw, -1)
    gv, gi = jax.lax.top_k(pr, K)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros((n, d))
    for kk in range(K):
        for e in range(E):
            m = gi[:, kk] == e
            h = jax.nn.silu(xt @ wg[e]) * (xt @ wu[e])
            ref += jnp.where(m[:, None], (h @ wd[e]) * gv[:, kk][:, None], 0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.reshape(y.shape)),
                               rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_gracefully():
    """Tiny capacity: output stays finite and bounded (tokens drop, not NaN)."""
    d, E = 8, 4
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (1, 32, d))
    y, _ = moe_ffn(x, jax.random.normal(ks[1], (d, E)) * 0.1,
                   jax.random.normal(ks[2], (E, d, 16)) * 0.1,
                   jax.random.normal(ks[3], (E, d, 16)) * 0.1,
                   jax.random.normal(ks[4], (E, 16, d)) * 0.1,
                   top_k=2, capacity_factor=0.25)
    assert bool(jnp.all(jnp.isfinite(y)))
