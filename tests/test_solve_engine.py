"""Backend parity for the solve engine: xla vs pallas (interpret mode on
CPU) against the dense oracle, across multi-RHS, odd leaf sizes and ranks.

Acceptance: matvec and solve take (n, k) right-hand sides on both backends
and agree with the dense oracle to 1e-6 in float64.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hmatrix
from repro.core.hck import build_hck, to_dense
from repro.core.kernels_fn import BaseKernel
from repro.kernels.registry import (SolveConfig, registered, resolve_backend,
                                    tile_config)

BACKENDS = ["xla", "pallas"]


def _problem(f64, *, n, levels, rank, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 4),
                          dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=1.5, jitter=1e-10)
    f = build_hck(x, levels=levels, rank=rank,
                  key=jax.random.PRNGKey(seed + 1), kernel=ker)
    return f, to_dense(f)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k", [1, 3])
@pytest.mark.parametrize("n,levels,rank", [
    (256, 3, 16),     # aligned leaves (n0 = 32)
    (108, 2, 16),     # odd leaf size (n0 = 27)
    (120, 2, 1),      # rank 1
])
def test_matvec_parity_vs_dense(f64, backend, k, n, levels, rank):
    f, a = _problem(f64, n=n, levels=levels, rank=rank)
    b = jax.random.normal(jax.random.PRNGKey(7), (n, k), dtype=jnp.float64)
    cfg = SolveConfig(backend=backend)
    got = hmatrix.matvec(f, b, cfg)
    assert got.shape == (n, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("k", [1, 3])
@pytest.mark.parametrize("n,levels,rank", [
    (256, 3, 16),
    (108, 2, 16),
    (120, 2, 1),
])
def test_solve_parity_vs_dense(f64, backend, k, n, levels, rank):
    f, a = _problem(f64, n=n, levels=levels, rank=rank)
    b = jax.random.normal(jax.random.PRNGKey(8), (n, k), dtype=jnp.float64)
    cfg = SolveConfig(backend=backend)
    ridge = 0.05
    got = hmatrix.solve(f, b, ridge=ridge, config=cfg)
    want = jnp.linalg.solve(a + ridge * jnp.eye(n, dtype=jnp.float64), b)
    assert got.shape == (n, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_apply_inverse_parity(f64, backend):
    """The structured inverse applies identically from the explicit blocks
    (xla) and the fused block-Cholesky pair (pallas leaf_solve)."""
    f, a = _problem(f64, n=256, levels=3, rank=16)
    b = jax.random.normal(jax.random.PRNGKey(9), (256, 2), dtype=jnp.float64)
    inv = hmatrix.invert(f, ridge=0.1)
    assert inv.linv is not None
    got = hmatrix.apply_inverse(inv, b, SolveConfig(backend=backend))
    want = jnp.linalg.solve(a + 0.1 * jnp.eye(256, dtype=jnp.float64), b)
    # single structured apply (no refinement): looser than solve's 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_single_vector_rhs_squeeze(f64, backend):
    f, a = _problem(f64, n=120, levels=2, rank=8)
    b = jax.random.normal(jax.random.PRNGKey(10), (120,), dtype=jnp.float64)
    cfg = SolveConfig(backend=backend)
    y = hmatrix.matvec(f, b, cfg)
    x = hmatrix.solve(f, b, ridge=0.1, config=cfg)
    assert y.shape == (120,) and x.shape == (120,)
    np.testing.assert_allclose(np.asarray(y), np.asarray(a @ b),
                               rtol=1e-6, atol=1e-6)


def test_default_config_matches_explicit(f64):
    f, _ = _problem(f64, n=256, levels=3, rank=16)
    b = jax.random.normal(jax.random.PRNGKey(11), (256, 2),
                          dtype=jnp.float64)
    y_default = hmatrix.matvec(f, b)
    y_auto = hmatrix.matvec(f, b, SolveConfig())
    np.testing.assert_allclose(np.asarray(y_default), np.asarray(y_auto))


def test_resolve_backend_auto_rules():
    # compiled execution (a real TPU): float32 + tile-friendly -> pallas
    tpu = SolveConfig(interpret=False)
    assert resolve_backend(tpu, "leaf_matvec", dtype=jnp.float32,
                           n0=64, r=16) == "pallas"
    # interpret mode is CPU emulation: auto never picks it
    cpu = SolveConfig()  # interpret=True default
    assert resolve_backend(cpu, "leaf_matvec", dtype=jnp.float32,
                           n0=64, r=16) == "xla"
    # float64 stays on the oracle-grade xla path unless forced
    assert resolve_backend(tpu, "leaf_matvec", dtype=jnp.float64,
                           n0=64, r=16) == "xla"
    # odd leaves fall back
    assert resolve_backend(tpu, "leaf_matvec", dtype=jnp.float32,
                           n0=27, r=16) == "xla"
    # degenerate hierarchy falls back
    assert resolve_backend(tpu, "leaf_matvec", dtype=jnp.float32,
                           n0=64, r=0) == "xla"
    # explicit override wins everywhere
    forced = SolveConfig(backend="pallas")
    assert resolve_backend(forced, "leaf_matvec", dtype=jnp.float64,
                           n0=27, r=0) == "pallas"
    # leaf_solve cannot row-tile: leaves past the VMEM budget fall back
    assert resolve_backend(tpu, "leaf_solve", dtype=jnp.float32,
                           n0=512, r=16) == "pallas"
    assert resolve_backend(tpu, "leaf_solve", dtype=jnp.float32,
                           n0=4096, r=16) == "xla"
    # leaf_matvec row-tiles, so the same shape stays on pallas
    assert resolve_backend(tpu, "leaf_matvec", dtype=jnp.float32,
                           n0=4096, r=16) == "pallas"


def test_tile_config_budget():
    t = tile_config("leaf_matvec", n0=512, r=64, k=8)
    assert t.fits and t.block_n0 == 512   # default leaf fits whole
    big = tile_config("leaf_matvec", n0=8192, r=64, k=8)
    assert big.fits and big.block_n0 < 8192 and 8192 % big.block_n0 == 0
    forced = tile_config("leaf_matvec", n0=512, r=64, k=8, leaf_block=128)
    assert forced.block_n0 == 128
    # non-divisor overrides snap down to a divisor instead of no-opping
    snapped = tile_config("leaf_matvec", n0=512, r=64, k=8, leaf_block=100)
    assert snapped.block_n0 == 64 and 512 % snapped.block_n0 == 0


def test_solveconfig_is_static_and_validated():
    assert hash(SolveConfig()) == hash(SolveConfig())
    assert SolveConfig().with_backend("xla") == SolveConfig(backend="xla")
    with pytest.raises(ValueError):
        SolveConfig(backend="cuda")


def test_registry_complete():
    stages = {s for s, _ in registered()}
    assert {"leaf_matvec", "leaf_solve", "leaf_project"} <= stages


@pytest.mark.parametrize("backend", BACKENDS)
def test_consumers_accept_solve_config(f64, backend):
    """krr/gp/kpca run end-to-end under a forced backend."""
    from repro.core import gp, kpca, krr

    cfg = SolveConfig(backend=backend)
    n = 128
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 3), dtype=jnp.float64)
    y = jnp.sin(x[:, 0]) + 0.1 * x[:, 1]
    ker = BaseKernel("gaussian", sigma=1.5, jitter=1e-8)

    model = krr.fit(x, y, kernel=ker, lam=1e-2, rank=8, leaf_size=32,
                    levels=2, key=jax.random.PRNGKey(1), solve_config=cfg)
    pred = model.predict(x[:8])
    assert pred.shape == (8,) and bool(jnp.all(jnp.isfinite(pred)))

    g = gp.fit_gp(x, y, kernel=ker, noise=0.1, rank=8, levels=2,
                  key=jax.random.PRNGKey(2), solve_config=cfg)
    assert bool(jnp.isfinite(g.log_marginal_likelihood(
        y[g.factors.tree.perm])))

    f = g.factors
    emb, evals = kpca.kpca_embed(f, 2, iters=8, key=jax.random.PRNGKey(3),
                                 solve_config=cfg)
    assert emb.shape == (n, 2) and bool(jnp.all(jnp.isfinite(emb)))
