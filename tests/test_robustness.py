"""Chaos suite: every fault class in repro.testing.faultinject is
DETECTED (a structured NumericalFailure naming the stage), RECOVERED (its
repro.runtime.recover ladder lands on a working rung) and the recovered
result still passes the f64 parity gates.  The CI chaos lane runs this
file under ``REPRO_STRICT_FINITE=1`` on the xla and pallas-interpret
backends (``REPRO_CHAOS_BACKEND``) and uploads the measured
detection/recovery matrix (``REPRO_CHAOS_MATRIX``) as an artifact.

Also pins the serving input-validation contract and the CG ε-breakdown
guard (``repro/solvers/cg.py``): zero-RHS columns, already-converged warm
starts and exactly-singular operators must produce finite iterates.
"""
import dataclasses
import json
import os
import threading
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hmatrix, krr
from repro.core.kernels_fn import BaseKernel
from repro.kernels.registry import SolveConfig
from repro.runtime import health, recover
from repro.runtime.health import NumericalFailure
from repro.serving.predict_service import ModelRegistry, PredictEngine
from repro.serving.serve_loop import KRRServeLoop
from repro.solvers.cg import pcg
from repro.testing import faultinject as fi

BACKEND = os.environ.get("REPRO_CHAOS_BACKEND", "xla")
CFG = SolveConfig(backend=BACKEND,
                  interpret=True if BACKEND == "pallas" else None,
                  checks=True)

#: measured per-fault-class outcomes; published as the CI chaos artifact
#: and asserted complete by the final test in this file.
MATRIX: dict[str, dict] = {}


def record(fault: str, **kw):
    """Merge one fault class's measured outcome into the matrix."""
    assert fault in fi.FAULT_CLASSES, f"unknown fault class {fault!r}"
    MATRIX.setdefault(fault, {}).update(kw)


@pytest.fixture(scope="module", autouse=True)
def _publish_matrix():
    yield
    path = os.environ.get("REPRO_CHAOS_MATRIX")
    if path:
        payload = {
            "backend": BACKEND,
            "strict_finite": health.strict_finite_env(),
            "fault_classes": {
                name: {"layer": layer, "description": desc,
                       **MATRIX.get(name, {})}
                for name, (layer, desc) in fi.FAULT_CLASSES.items()},
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)


@pytest.fixture(scope="module")
def prob(f64):
    """256-point f64 regression problem + a fitted model (checks on)."""
    kx, kw, kn, kq = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(kx, (256, 5), jnp.float64)
    w = jax.random.normal(kw, (5, 2))
    y = x @ w + 0.05 * jax.random.normal(kn, (256, 2))
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-8)
    model = krr.fit(x, y, kernel=ker, lam=1e-2, rank=16, leaf_size=32,
                    levels=3, solve_config=CFG)
    queries = jax.random.normal(kq, (64, 5), jnp.float64)
    return types.SimpleNamespace(x=x, y=y, kernel=ker, model=model,
                                 queries=queries, lam=1e-2)


def _spd_problem(n: int, k: int, seed: int):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(ka, (n, n), jnp.float64)
    A = a @ a.T / n + jnp.eye(n, dtype=jnp.float64)
    b = jax.random.normal(kb, (n, k), jnp.float64)
    return A, b


# ---------------------------------------------------------------------------
# gating: checks must cost nothing (and fire) exactly when asked
# ---------------------------------------------------------------------------

def test_checks_gating(monkeypatch, prob):
    monkeypatch.delenv("REPRO_STRICT_FINITE", raising=False)
    assert not health.checks_enabled(None)
    assert not health.checks_enabled(SolveConfig())
    monkeypatch.setenv("REPRO_STRICT_FINITE", "1")
    assert health.checks_enabled(None)
    assert health.checks_enabled(SolveConfig())
    assert not health.checks_enabled(SolveConfig(checks=False))
    monkeypatch.delenv("REPRO_STRICT_FINITE")
    assert health.checks_enabled(SolveConfig(checks=True))
    # checks-off probes are silent even on poisoned factors
    bad = fi.poison_factor(prob.model.factors, "u")
    assert health.probe_factors(bad, SolveConfig(checks=False)) is False
    # and raise the moment force=True (the guarded-call contract)
    with pytest.raises(NumericalFailure):
        health.probe_factors(bad, SolveConfig(checks=False), force=True)


# ---------------------------------------------------------------------------
# build-layer faults: poisoned factors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fault,field,value,stage", [
    ("factor_nan", "u", float("nan"), "build_cross"),
    ("factor_inf", "adiag", float("inf"), "build_gram"),
    ("sigma_nan", "sigma", float("nan"), "build_gram"),
])
def test_poisoned_factor_detect_recover(prob, fault, field, value, stage):
    clean = prob.model.factors
    bad = fi.poison_factor(clean, field, leaf=1, value=value)

    with pytest.raises(NumericalFailure) as ei:
        health.probe_factors(bad, CFG)
    err = ei.value
    assert err.stage == stage
    assert err.statistic == "nonfinite_count"
    assert field in err.detail
    if field in ("u", "adiag"):
        assert err.leaf == 1
    record(fault, detected=True, stage=err.stage)

    repaired, audit = recover.repair_factors(bad, prob.kernel, CFG)
    assert audit.recovered and not audit.attempts[0].ok
    # frozen hierarchy + untouched inputs => the repair is parity-exact
    np.testing.assert_allclose(np.asarray(repaired.u, np.float64),
                               np.asarray(clean.u, np.float64), atol=1e-9)
    np.testing.assert_allclose(np.asarray(repaired.adiag, np.float64),
                               np.asarray(clean.adiag, np.float64),
                               atol=1e-9)
    for s_new, s_old in zip(repaired.sigma, clean.sigma):
        np.testing.assert_allclose(np.asarray(s_new, np.float64),
                                   np.asarray(s_old, np.float64), atol=1e-9)
    record(fault, recovered=True, rungs=audit.rungs)


def test_repair_factors_is_noop_on_clean_factors(prob):
    repaired, audit = recover.repair_factors(prob.model.factors, prob.kernel,
                                             CFG)
    assert repaired is prob.model.factors
    assert audit.rungs == ["probe"] and not audit.recovered


# ---------------------------------------------------------------------------
# inversion-layer faults: indefinite Schur complements
# ---------------------------------------------------------------------------

def test_indefinite_leaf_detect_recover(prob):
    lam = prob.lam
    bad = fi.indefinite_leaf(prob.model.factors, leaf=2, shift=5 * lam)

    _, lo = hmatrix.invert_with_leaf(bad, lam, CFG)
    with pytest.raises(NumericalFailure) as ei:
        health.probe_leaf_factor(lo, CFG)
    err = ei.value
    assert err.stage == "leaf_factor"
    assert err.statistic == "min_schur_cholesky_diag"
    assert err.leaf == 2
    record("indefinite_leaf", detected=True, stage=err.stage)

    g = recover.invert_guarded(bad, lam, CFG, kernel=prob.kernel)
    assert not g.audit.attempts[0].ok and g.audit.recovered
    assert g.ridge > lam            # the ridge-escalation rung held

    # parity: the recovered inverse solves ITS operator to oracle accuracy
    n = bad.x_sorted.shape[0]
    b = jax.random.normal(jax.random.PRNGKey(7), (n, 2), jnp.float64)
    alpha = hmatrix.solve_with_inverse(g.factors, g.inverse, b,
                                       ridge=g.ridge, config=g.config)
    kd = hmatrix.matvec_dense_reference(
        g.factors, jnp.eye(n, dtype=jnp.float64))
    resid = kd @ alpha + g.ridge * alpha - b
    rel = float(jnp.linalg.norm(resid) / jnp.linalg.norm(b))
    assert rel < 1e-8
    record("indefinite_leaf", recovered=True, rungs=g.audit.rungs,
           parity_rel_residual=rel)


def test_bf16_ridge_floor_detect_recover(prob):
    """PR 7's bf16 ridge floor as a live fault: inversion of bf16-built
    factors at a ridge far below n0·eps_bf16 NaNs the leaf Schur
    Cholesky; the ladder's precision-promotion rung (refit_frozen at f32,
    ORIGINAL ridge) must repair it without inflating the ridge."""
    # the ridge floor is a PRECISION fault, not a backend fault: the
    # pallas interpreter upcasts bf16 matmuls to f32 internally, so the
    # rounding that kills the Schur complement only reproduces through
    # the xla lane — pin it, keeping the fault class measurable from
    # every chaos backend
    cfg = SolveConfig(backend="xla", checks=True, precision="bf16")
    # jitter far below the bf16 factor error: the λ'-splitting diagonal
    # no longer masks the rounding, so the Schur complement goes
    # indefinite at any reasonable ridge — the PR 7 failure, reproduced
    ker = BaseKernel("gaussian", sigma=2.0, jitter=1e-6)
    x32 = prob.x.astype(jnp.float32)
    from repro.core.hck import build_hck

    f = build_hck(x32, levels=3, rank=16, key=jax.random.PRNGKey(1),
                  kernel=ker, config=cfg)
    assert health.probe_factors(f, cfg)     # the build itself is finite
    ridge = 1e-3

    _, lo = hmatrix.invert_with_leaf(f, ridge, cfg)
    with pytest.raises(NumericalFailure) as ei:
        health.probe_leaf_factor(lo, cfg)
    assert ei.value.stage == "leaf_factor"
    record("bf16_ridge_floor", detected=True, stage=ei.value.stage)

    g = recover.invert_guarded(f, ridge, cfg, kernel=ker, jitter_rungs=0)
    assert not g.audit.attempts[0].ok
    assert g.audit.attempts[-1].rung == "promote:f32"
    assert g.ridge == ridge           # recovered at the ORIGINAL ridge

    assert g.config.precision == "f32"      # follow-up solves promote too
    n = f.x_sorted.shape[0]
    b = jax.random.normal(jax.random.PRNGKey(8), (n, 1), jnp.float32)
    alpha = hmatrix.solve_with_inverse(g.factors, g.inverse, b,
                                       ridge=g.ridge, config=g.config)
    assert bool(jnp.isfinite(alpha).all())
    # f64 oracle gate on the recovered solve
    f64f = recover._cast_float(g.factors, jnp.float64)
    kd = hmatrix.matvec_dense_reference(
        f64f, jnp.eye(n, dtype=jnp.float64))
    a64 = alpha.astype(jnp.float64)
    resid = kd @ a64 + g.ridge * a64 - b.astype(jnp.float64)
    rel = float(jnp.linalg.norm(resid) / jnp.linalg.norm(b))
    assert rel < 1e-2
    record("bf16_ridge_floor", recovered=True, rungs=g.audit.rungs,
           parity_rel_residual=rel)


# ---------------------------------------------------------------------------
# CG ε-breakdown guard (solvers/cg.py) — the pinned edge cases
# ---------------------------------------------------------------------------

def test_cg_zero_rhs_column_stays_finite(f64):
    A, b = _spd_problem(24, 3, seed=3)
    b = b.at[:, 1].set(0.0)
    res = pcg(lambda v: A @ v, b, tol=1e-10, maxiter=60)
    assert bool(jnp.isfinite(res.x).all())
    assert bool(jnp.isfinite(res.residuals).all())
    np.testing.assert_allclose(np.asarray(res.x[:, 1]), 0.0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(
        jnp.linalg.solve(A, b)), atol=1e-7)


def test_cg_already_converged_warm_start(f64):
    A, b = _spd_problem(24, 2, seed=4)
    x_star = jnp.linalg.solve(A, b)
    res = pcg(lambda v: A @ v, b, tol=1e-8, maxiter=40, x0=x_star)
    assert bool(res.converged)
    assert int(res.iterations) == 0
    assert bool(jnp.isfinite(res.x).all())
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_star),
                               atol=1e-10)


def test_cg_exactly_singular_operator_finite_iterates(f64):
    c = jax.random.normal(jax.random.PRNGKey(5), (16, 5), jnp.float64)
    A = c @ c.T                       # rank 5, exactly singular
    v = jax.random.normal(jax.random.PRNGKey(6), (16, 2), jnp.float64)
    b_consistent = A @ v
    res = pcg(lambda u: A @ u, b_consistent, tol=1e-9, maxiter=64)
    assert bool(jnp.isfinite(res.x).all())
    assert bool(jnp.isfinite(res.residuals).all())
    rel = float(jnp.linalg.norm(A @ res.x - b_consistent)
                / jnp.linalg.norm(b_consistent))
    assert rel < 1e-7
    # inconsistent RHS (a null-space component): can never converge, but
    # the ε guard must keep every iterate finite
    b_bad = b_consistent + jnp.linalg.svd(A)[0][:, -1:]
    res2 = pcg(lambda u: A @ u, b_bad, tol=1e-9, maxiter=64)
    assert bool(jnp.isfinite(res2.x).all())
    assert bool(jnp.isfinite(res2.residuals).all())


# ---------------------------------------------------------------------------
# solver-layer faults: preconditioner / operator / collective
# ---------------------------------------------------------------------------

def test_bad_preconditioner_detect_recover(f64):
    A, b = _spd_problem(48, 2, seed=9)
    mv = lambda v: A @ v                                       # noqa: E731
    badM = fi.bad_preconditioner()
    res = pcg(mv, b, precond=badM, tol=1e-10, maxiter=40, flexible=False)
    assert not bool(res.converged)
    with pytest.raises(NumericalFailure) as ei:
        health.probe_cg(res, tol=1e-10, force=True)
    assert ei.value.stage == "solvers.cg"
    assert ei.value.statistic.startswith("residual_")
    record("cg_bad_preconditioner", detected=True, stage=ei.value.stage,
           verdict=ei.value.statistic)

    g = recover.pcg_guarded(mv, b, precond=badM,
                            fresh_precond=lambda: None,
                            tol=1e-10, maxiter=100, flexible=False)
    assert not g.audit.attempts[0].ok
    assert g.audit.attempts[-1].rung == "re-precondition"
    np.testing.assert_allclose(np.asarray(g.x),
                               np.asarray(jnp.linalg.solve(A, b)),
                               atol=1e-7)
    record("cg_bad_preconditioner", recovered=True, rungs=g.audit.rungs)


def test_nonsymmetric_column_detect_recover(f64):
    A, b = _spd_problem(48, 2, seed=10)
    mv = lambda v: A @ v                                       # noqa: E731
    bad_mv = fi.nonsymmetric_column(mv, col=1, eps=2.0)
    res = pcg(bad_mv, b, tol=1e-10, maxiter=40)
    assert not bool(res.converged)
    with pytest.raises(NumericalFailure) as ei:
        health.probe_cg(res, tol=1e-10, force=True)
    assert ei.value.stage == "solvers.cg"
    record("cg_nonsymmetric_column", detected=True, stage=ei.value.stage,
           verdict=ei.value.statistic)

    # the operator fault is permanent: every CG rung fails, the ladder
    # terminates at the exact-solve bypass
    g = recover.pcg_guarded(bad_mv, b, tol=1e-10, maxiter=40,
                            exact_solve=lambda bb: jnp.linalg.solve(A, bb))
    assert g.audit.attempts[-1].rung == "exact fallback"
    assert all(not a.ok for a in g.audit.attempts[:-1])
    np.testing.assert_allclose(np.asarray(g.x),
                               np.asarray(jnp.linalg.solve(A, b)),
                               atol=1e-10)
    record("cg_nonsymmetric_column", recovered=True, rungs=g.audit.rungs)


def test_collective_nan_detect_recover(f64):
    A, b = _spd_problem(32, 2, seed=11)
    mv = lambda v: A @ v                                       # noqa: E731
    bad_dot, state = fi.poisoned_dot(after=3)
    res = pcg(mv, b, tol=1e-10, maxiter=30, dot=bad_dot)
    assert state["calls"] > 3         # the fault actually fired at runtime
    with pytest.raises(NumericalFailure) as ei:
        health.probe_cg(res, tol=1e-10, force=True)
    assert ei.value.stage == "solvers.cg"
    assert ei.value.statistic == "residual_nonfinite"
    record("collective_nan", detected=True, stage=ei.value.stage)

    bad_dot2, _ = fi.poisoned_dot(after=3)
    g = recover.pcg_guarded(mv, b, tol=1e-10, maxiter=60, dot=bad_dot2,
                            fresh_dot=lambda: None)
    assert not g.audit.attempts[0].ok
    assert g.audit.attempts[-1].rung == "cold restart"
    np.testing.assert_allclose(np.asarray(g.x),
                               np.asarray(jnp.linalg.solve(A, b)),
                               atol=1e-7)
    record("collective_nan", recovered=True, rungs=g.audit.rungs)


# ---------------------------------------------------------------------------
# kernel-system faults: tile-DB corruption
# ---------------------------------------------------------------------------

def test_tile_db_corruption_degrades_and_repairs(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_TILE_DB", str(tmp_path / "tile_db.json"))
    path = fi.corrupt_tile_db()
    db = autotune.get_db()
    assert db.corrupt                 # detected, flagged
    assert db.entries == {}           # degraded to heuristics, no raise
    record("tile_db_corruption", detected=True, stage="kernels.autotune")

    # a consult on the corrupt DB must fall back to the heuristic path
    blk = autotune.lookup_block("build_gram", n0=64, r=16, k=16)
    assert blk is None or isinstance(blk, int)

    # the next save rewrites the file; a reload sees a healthy DB
    db.put("probe", {"block_n0": 32})
    db.save()
    autotune.reset_db()
    db2 = autotune.get_db()
    assert not db2.corrupt
    assert db2.get("probe") == {"block_n0": 32}
    record("tile_db_corruption", recovered=True,
           rungs=["degrade-to-heuristics", "save-rewrites"])
    autotune.reset_db()               # drop the tmp-path singleton


# ---------------------------------------------------------------------------
# update-layer faults: poisoned cached inverse
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def arrivals(prob):
    kx, kn = jax.random.split(jax.random.PRNGKey(13))
    x_new = jax.random.normal(kx, (16, 5), jnp.float64)
    w = jnp.linalg.lstsq(prob.x, prob.y)[0]
    y_new = x_new @ w + 0.05 * jax.random.normal(kn, (16, 2))
    return x_new, y_new


def test_update_poisoned_cache_detect(prob, arrivals):
    x_new, y_new = arrivals
    bad = fi.poison_cached_inverse(prob.model)
    with pytest.raises(NumericalFailure) as ei:
        bad.update(x_new, y_new, refresh="inverse")
    assert ei.value.stage == "leaf_update"
    assert ei.value.leaf == 0
    record("update_poisoned_cache", detected=True, stage=ei.value.stage)


def test_update_poisoned_cache_recover_parity(prob, arrivals):
    x_new, y_new = arrivals
    bad = fi.poison_cached_inverse(prob.model)
    m_rec, info, audit = recover.update_guarded(bad, x_new, y_new,
                                                refresh="inverse")
    assert not audit.attempts[0].ok and audit.recovered
    assert audit.attempts[-1].rung.startswith("re-precondition")
    assert bool(info.converged)

    # parity: the recovered model must match the clean model's update
    # bit-for-bit in routing and to f64 round-off in predictions
    m_clean, _ = prob.model.update(x_new, y_new, refresh="inverse")
    z_rec = m_rec.predict(prob.queries)
    z_clean = m_clean.predict(prob.queries)
    assert bool(jnp.isfinite(z_rec).all())
    np.testing.assert_allclose(np.asarray(z_rec), np.asarray(z_clean),
                               atol=1e-8)
    record("update_poisoned_cache", recovered=True, rungs=audit.rungs)


def test_update_refresh_exact_matches_inverse(prob, arrivals):
    """refresh='exact' (the ladder's terminal rung) is numerically
    independent of all cached state yet parity-exact with the bordered
    path."""
    x_new, y_new = arrivals
    m_exact, info = prob.model.update(x_new, y_new, refresh="exact")
    m_inv, _ = prob.model.update(x_new, y_new, refresh="inverse")
    assert bool(info.converged) and info.iterations == 0
    np.testing.assert_allclose(np.asarray(m_exact.predict(prob.queries)),
                               np.asarray(m_inv.predict(prob.queries)),
                               atol=1e-8)


# ---------------------------------------------------------------------------
# serving faults: canary gate, transactional publish, degraded mode
# ---------------------------------------------------------------------------

def _registry(prob, **kw):
    kw.setdefault("canary", prob.model.factors.x_sorted[:32])
    kw.setdefault("canary_tol", 0.5)
    kw.setdefault("min_bucket", 32)
    kw.setdefault("max_bucket", 256)
    return ModelRegistry(prob.model, **kw)


def _snapshot(reg):
    return (reg.live_version, tuple(reg.versions()), reg._next,
            id(reg.live), id(reg.live.engine), reg.stats["swaps"])


def test_canary_blocks_poisoned_model_under_live_traffic(prob, arrivals):
    x_new, y_new = arrivals
    reg = _registry(prob)
    loop = KRRServeLoop(reg)
    stop = threading.Event()
    errors: list = []

    def worker():
        k = jax.random.PRNGKey(17)
        while not stop.is_set():
            k, sub = jax.random.split(k)
            q = jax.random.normal(sub, (48, 5), jnp.float64)
            try:
                loop.serve(q)
            except Exception as e:    # surfaced to the main thread
                errors.append(e)
                return

    t = threading.Thread(target=worker)
    t.start()
    try:
        m_up, _ = prob.model.update(x_new, y_new, refresh="inverse")
        bad = fi.poisoned_model(m_up)
        before = _snapshot(reg)
        with pytest.raises(NumericalFailure) as ei:
            reg.publish(bad)
        assert ei.value.stage == "serving.canary"
        record("serving_poisoned_model", detected=True, stage=ei.value.stage)
        # auto-rollback == the swap never happened: bitwise-unchanged state
        assert _snapshot(reg) == before
        assert reg.stats["canary_rejects"] == 1
        assert reg.stats["last_reject"]["stage"] == "serving.canary"
        # the clean update still publishes under the same traffic
        v2 = reg.publish(m_up)
        assert reg.live_version == v2
        record("serving_poisoned_model", recovered=True,
               rungs=["canary-reject", "publish-clean"])
    finally:
        stop.set()
        t.join(timeout=30)
    assert not errors
    assert len(loop.responses) > 0
    for r in loop.responses:          # no request ever saw a non-finite z
        assert bool(jnp.isfinite(r.z).all())
        assert not r.degraded
    assert set(loop.versions_served) <= {1, 2}


def test_canary_rejects_drifted_but_finite_model(prob):
    reg = _registry(prob)
    drifted = dataclasses.replace(
        prob.model, plan=dataclasses.replace(
            prob.model.plan, w_leaf=prob.model.plan.w_leaf * 3.0))
    with pytest.raises(NumericalFailure) as ei:
        reg.publish(drifted, canary_tol=1e-3)
    assert ei.value.statistic == "canary_drift"
    assert reg.live_version == 1


def test_update_and_publish_is_transactional(prob, arrivals):
    x_new, y_new = arrivals
    # v1's model carries a poisoned cached inverse: the update itself
    # fails midway, AFTER the registry call started
    poisoned = types.SimpleNamespace(
        **{**vars(prob), "model": fi.poison_cached_inverse(prob.model)})
    reg = _registry(poisoned)
    before = _snapshot(reg)
    with pytest.raises(NumericalFailure):
        reg.update_and_publish(x_new, y_new, refresh="inverse")
    assert _snapshot(reg) == before   # registry state bitwise unchanged

    # poisoned labels defeat EVERY rung (no refresh mode can fix NaN
    # targets): the guarded ladder runs dry — still transactional
    with pytest.raises(recover.RecoveryExhausted):
        reg.update_and_publish(x_new, y_new * jnp.nan, refresh="inverse",
                               guarded=True)
    assert _snapshot(reg) == before

    # guarded=True climbs the recovery ladder and commits
    v2, info = reg.update_and_publish(x_new, y_new, refresh="inverse",
                                      guarded=True)
    assert reg.live_version == v2 and bool(info.converged)
    z, v = reg.predict(prob.queries)
    assert v == v2 and bool(jnp.isfinite(z).all())


def test_serve_loop_degrades_to_last_good_version(prob, arrivals):
    x_new, y_new = arrivals
    reg = _registry(prob)
    loop = KRRServeLoop(reg, max_retries=1)
    q = prob.queries[:32]
    assert loop.serve(q).version == 1           # v1 becomes last-good

    m_up, _ = prob.model.update(x_new, y_new, refresh="inverse")
    reg.publish(m_up)
    # v2 passed its canary, then goes bad in production (post-publish)
    fi.hijack_live_engine(
        reg, lambda e: fi.FlakyEngine(e, fail_first=-1, mode="nan"))
    out = loop.serve(q)
    assert out.degraded and out.version == 1
    assert bool(jnp.isfinite(out.z).all())
    assert "nonfinite" in out.failure
    st = loop.stats()
    assert st["degraded_batches"] == 1
    assert st["failures"] == 2                  # max_retries + 1 attempts
    record("serving_flaky_engine", detected=True, stage="serve")
    record("serving_flaky_engine", recovered=True,
           rungs=["retry", "degrade-to-last-good"])


def test_serve_loop_retry_heals_transient_fault(prob):
    reg = _registry(prob)
    loop = KRRServeLoop(reg, max_retries=2)
    fi.hijack_live_engine(
        reg, lambda e: fi.FlakyEngine(e, fail_first=1, mode="raise"))
    out = loop.serve(prob.queries[:32])
    assert not out.degraded and out.retries == 1
    assert "engine down" in out.failure
    assert bool(jnp.isfinite(out.z).all())
    assert loop.stats()["failures"] == 1


def test_serve_loop_deadline_miss_retries(prob):
    reg = _registry(prob)
    loop = KRRServeLoop(reg)
    loop.serve(prob.queries[:32])               # warm the bucket first
    fi.hijack_live_engine(
        reg, lambda e: fi.FlakyEngine(e, fail_first=1, mode="slow",
                                      delay_s=0.5))
    loop.deadline_s = 0.25
    out = loop.serve(prob.queries[:32])
    assert not out.degraded and out.retries == 1
    assert loop.stats()["deadline_misses"] == 1
    assert "deadline_s" in out.failure


# ---------------------------------------------------------------------------
# serving input validation (front-door contract)
# ---------------------------------------------------------------------------

def test_serving_input_validation(prob):
    engine = PredictEngine(prob.model.factors, prob.model.plan, prob.kernel,
                           config=CFG, min_bucket=32, max_bucket=256)
    with pytest.raises(ValueError, match="2-D"):
        engine.apply(prob.queries[0])
    with pytest.raises(ValueError, match="0 features"):
        engine.apply(jnp.zeros((4, 0), jnp.float64))
    with pytest.raises(ValueError, match="feature dim"):
        engine.apply(jnp.zeros((4, 3), jnp.float64))
    with pytest.raises(ValueError, match="dtype"):
        engine.apply(prob.queries.astype(jnp.float32))
    loop = KRRServeLoop(_registry(prob))
    with pytest.raises(ValueError, match="micro_batch"):
        loop.run(prob.queries, 0)
    with pytest.raises(ValueError, match="micro_batch"):
        loop.run(prob.queries, -4)
    # a malformed batch is a caller bug: it must NOT be retried/degraded
    with pytest.raises(ValueError, match="feature dim"):
        loop.serve(jnp.zeros((4, 3), jnp.float64))


# ---------------------------------------------------------------------------
# the matrix itself: every declared fault class was measured
# ---------------------------------------------------------------------------

def test_zz_fault_matrix_covers_every_class():
    missing = set(fi.FAULT_CLASSES) - set(MATRIX)
    assert not missing, f"fault classes without measurements: {missing}"
    for name, row in MATRIX.items():
        assert row.get("detected"), f"{name} was never detected"
        assert row.get("recovered"), f"{name} was never recovered"
