"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED same-family config, runs one train step and one decode step on CPU
with finite outputs and correct shapes.  The FULL configs are exercised via
the dry-run only (see launch/dryrun.py + EXPERIMENTS.md)."""
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, get_shape, list_archs
from repro.models.model_zoo import smoke_step
from repro.models.transformer import N_CODEBOOKS

ARCHS = list_archs()


def test_all_ten_archs_registered():
    assert set(ARCHS) == {
        "zamba2-7b", "qwen2-vl-7b", "deepseek-67b", "deepseek-7b",
        "granite-3-2b", "qwen3-32b", "mixtral-8x22b", "arctic-480b",
        "mamba2-780m", "musicgen-medium"}


def test_exact_assigned_hyperparameters():
    """Full configs carry the assignment-table numbers verbatim."""
    c = get_arch("deepseek-67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (95, 8192, 64, 8, 22016, 102400)
    c = get_arch("mixtral-8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.n_experts, c.top_k) == (56, 6144, 48, 8, 16384, 32768, 8, 2)
    c = get_arch("arctic-480b")
    assert (c.n_experts, c.top_k, c.dense_residual) == (128, 2, True)
    c = get_arch("mamba2-780m")
    assert (c.n_layers, c.d_model, c.ssm_state, c.n_heads) == (48, 1536, 128, 0)
    c = get_arch("zamba2-7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = get_arch("qwen2-vl-7b")
    assert (c.n_kv_heads, c.mrope, c.vocab) == (4, True, 152064)
    c = get_arch("qwen3-32b")
    assert (c.qk_norm, c.d_ff) == (True, 25600)
    c = get_arch("granite-3-2b")
    assert (c.n_layers, c.vocab) == (40, 49155)
    c = get_arch("musicgen-medium")
    assert (c.n_layers, c.d_model, c.vocab) == (48, 1536, 2048)
    c = get_arch("deepseek-7b")
    assert (c.n_layers, c.d_model, c.n_kv_heads) == (30, 4096, 32)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_arch(arch)
    out = smoke_step(cfg, get_shape("train_4k"))
    assert jnp.isfinite(out["loss"])
    # gradients exist and are finite for every parameter
    import jax

    for g in jax.tree.leaves(out["grads"]):
        assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_arch(arch)
    out = smoke_step(cfg, get_shape("decode_32k"))
    logits = out["logits"]
    assert bool(jnp.all(jnp.isfinite(logits)))
    rcfg = cfg.reduced()
    want_v = rcfg.vocab * (N_CODEBOOKS if cfg.family == "audio" else 1)
    assert logits.shape == (2, 1, want_v)


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-7b", "deepseek-7b",
                                  "musicgen-medium"])
def test_prefill_step_smoke(arch):
    cfg = get_arch(arch)
    out = smoke_step(cfg, get_shape("prefill_32k"))
    assert bool(jnp.all(jnp.isfinite(out["logits"])))


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-7b", "granite-3-2b"])
def test_long_decode_smoke(arch):
    """long_500k cells (reduced): SSM/hybrid native; attention archs via the
    paper's HCK backend (DESIGN.md §Arch-applicability)."""
    cfg = get_arch(arch)
    out = smoke_step(cfg, get_shape("long_500k"))
    assert bool(jnp.all(jnp.isfinite(out["logits"])))
