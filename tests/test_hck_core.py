"""Core HCK correctness: the factor algebra against the paper's definitions.

Oracles: dense_reference_kernel evaluates Eq. 13-16 directly; to_dense
reconstructs the matrix from factors; numpy.linalg does the dense algebra.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hmatrix, oos
from repro.core.hck import build_hck, dense_reference_kernel, to_dense
from repro.core.kernels_fn import BaseKernel


def test_factors_match_kernel_definition(small_problem):
    """to_dense(factors) == direct evaluation of Eq. 13-16."""
    x, ker, f = small_problem
    a = to_dense(f)
    ref = dense_reference_kernel(f.x_sorted, f, ker)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ref),
                               rtol=1e-10, atol=1e-12)


def test_theorem6_positive_definite(small_problem):
    """Thm 6: K_hck is strictly PD for a strictly PD base kernel."""
    _, _, f = small_problem
    ev = jnp.linalg.eigvalsh(to_dense(f))
    assert float(ev.min()) > 0


@pytest.mark.parametrize("name", ["gaussian", "laplace", "imq"])
def test_pd_all_base_kernels(f64, name):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (128, 4), dtype=jnp.float64)
    ker = BaseKernel(name, sigma=1.5, jitter=1e-10)
    f = build_hck(x, levels=2, rank=8, key=key, kernel=ker)
    ev = jnp.linalg.eigvalsh(to_dense(f))
    assert float(ev.min()) > -1e-9


def test_proposition1_exact_on_landmarks(f64):
    """Prop 1 / Prop 5: k_hck(x, x') == k(x, x') when the points ARE
    landmarks along the relevant paths."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (64, 3), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=2.0, jitter=0.0)
    # one level: root landmarks only (compositional kernel)
    f = build_hck(x, levels=1, rank=16, key=jax.random.PRNGKey(4), kernel=ker)
    a = to_dense(f)
    k_exact = ker.cross(f.x_sorted, f.x_sorted)
    # rows where the point is a root landmark must be exact everywhere
    lm = f.landmarks[0][0]                                 # (r, d)
    d2 = jnp.sum((f.x_sorted[:, None] - lm[None]) ** 2, -1)
    is_lm = np.asarray(jnp.any(d2 < 1e-20, axis=1))
    assert is_lm.sum() > 0
    np.testing.assert_allclose(np.asarray(a)[is_lm], np.asarray(k_exact)[is_lm],
                               rtol=1e-8, atol=1e-10)


def test_theorem4_compositional_beats_nystrom(f64):
    """Thm 4: ||K - K_comp|| < ||K - K_nys|| (same landmarks).

    shared_landmarks=True makes the hierarchy collapse to k_compositional
    (the §4.2 remark), with the root landmark set playing Nystrom's."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (256, 4), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=1.0, jitter=1e-12)
    f = build_hck(x, levels=3, rank=16, key=jax.random.PRNGKey(6),
                  kernel=ker, shared_landmarks=True)
    k_exact = ker.cross(f.x_sorted, f.x_sorted)
    k_comp = to_dense(f)
    lm = f.landmarks[0][0]
    kxm = ker.cross(f.x_sorted, lm)
    kmm = ker.gram(lm)
    k_nys = kxm @ jnp.linalg.solve(kmm, kxm.T)
    err_comp = jnp.linalg.norm(k_exact - k_comp)
    err_nys = jnp.linalg.norm(k_exact - k_nys)
    assert float(err_comp) < float(err_nys)


def test_matvec_algorithm1(small_problem):
    x, ker, f = small_problem
    a = to_dense(f)
    b = jax.random.normal(jax.random.PRNGKey(7), (f.n, 3), dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(hmatrix.matvec(f, b)),
                               np.asarray(a @ b), rtol=1e-9, atol=1e-10)
    # single-vector path
    np.testing.assert_allclose(np.asarray(hmatrix.matvec(f, b[:, 0])),
                               np.asarray(a @ b[:, 0]), rtol=1e-9, atol=1e-10)


def test_inversion_algorithm2(small_problem):
    x, ker, f = small_problem
    a = to_dense(f)
    b = jax.random.normal(jax.random.PRNGKey(8), (f.n, 2), dtype=jnp.float64)
    for ridge in (0.01, 0.5):
        got = hmatrix.solve(f, b, ridge=ridge)
        want = jnp.linalg.solve(a + ridge * jnp.eye(f.n), b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-7, atol=1e-8)


def test_logdet_from_algorithm2(small_problem):
    x, ker, f = small_problem
    a = to_dense(f)
    for ridge in (0.01, 0.5):
        got = float(hmatrix.logdet(f, ridge=ridge))
        _, want = jnp.linalg.slogdet(a + ridge * jnp.eye(f.n))
        assert got == pytest.approx(float(want), rel=1e-9)


def test_oos_algorithm3(small_problem):
    """w^T k_hck(X, x) via Algorithm 3 == explicit Eq. 13-16 vector."""
    x, ker, f = small_problem
    q = jax.random.normal(jax.random.PRNGKey(9), (9, x.shape[1]),
                          dtype=jnp.float64)
    w = jax.random.normal(jax.random.PRNGKey(10), (f.n, 2), dtype=jnp.float64)
    got = oos.predict(f, w, q, ker)
    vref = jnp.stack([oos.oos_vector_reference(f, qq, ker) for qq in q])
    np.testing.assert_allclose(np.asarray(got), np.asarray(vref @ w),
                               rtol=1e-8, atol=1e-10)


def test_training_points_self_consistency(small_problem):
    """Predicting AT a training point must reproduce the matvec row: the
    kernel treats x in leaf j via the same formulas."""
    x, ker, f = small_problem
    a = to_dense(f)
    w = jax.random.normal(jax.random.PRNGKey(11), (f.n,), dtype=jnp.float64)
    # query exactly at the first point of leaf 0
    q = f.x_sorted[:1]
    got = float(oos.predict(f, w, q, ker)[0])
    # reference: row 0 of A -- except diag jitter: the OOS kernel for a point
    # coinciding with a training point does not carry the lambda' delta
    # (effective per-leaf jitter is jitter * leaf_size, see BaseKernel.gram)
    row = np.asarray(a)[0].copy()
    row[0] -= ker.jitter * f.leaf_size
    assert got == pytest.approx(float(row @ np.asarray(w)), rel=1e-8)


def test_levels_zero_degenerates_to_exact(f64):
    key = jax.random.PRNGKey(12)
    x = jax.random.normal(key, (32, 3), dtype=jnp.float64)
    ker = BaseKernel("gaussian", sigma=1.0, jitter=1e-10)
    f = build_hck(x, levels=0, rank=8, key=key, kernel=ker)
    np.testing.assert_allclose(np.asarray(to_dense(f)),
                               np.asarray(ker.gram(f.x_sorted)),
                               rtol=1e-12, atol=1e-12)
