"""Gaussian-process hyper-parameter estimation by maximum likelihood — the
paper's §6 'avenue of future work', implemented with the Algorithm-2
structured logdet and full autodiff through the hierarchy.

    PYTHONPATH=src python examples/gp_mle.py

Maximizes Eq. 25's log marginal likelihood over (log sigma, log noise) with
plain gradient descent; each objective evaluation is O(n r^2) instead of
the O(n^3) the paper flags as the obstacle.  The partition/landmark
randomness is frozen (paper §5.1: stable surfaces are a prerequisite for
parameter estimation — and the HCK surface is the stable one).
"""
import jax
import jax.numpy as jnp

from repro.core import gp


def main():
    key = jax.random.PRNGKey(0)
    n, d = 2048, 4
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.uniform(k1, (n, d))
    true_sigma, true_noise = 0.35, 0.05
    # draw y from a GP-ish generative process at the true hyper-params
    centers = jax.random.uniform(k2, (64, d))
    w = jax.random.normal(k3, (64,))
    dist2 = jnp.sum((x[:, None] - centers[None]) ** 2, -1)
    f = jnp.exp(-dist2 / (2 * true_sigma ** 2)) @ w
    f = f / jnp.std(f)
    y = f + true_noise * jax.random.normal(key, (n,))

    nll = gp.mle_objective(x, y, levels=4, rank=64, key=jax.random.PRNGKey(7))
    grad = jax.jit(jax.value_and_grad(nll, argnums=(0, 1)))

    log_sigma = jnp.log(jnp.array(1.0))     # deliberately misspecified init
    log_noise = jnp.log(jnp.array(0.5))
    lr = 0.05
    print(f"true: sigma={true_sigma} noise={true_noise}")
    for step in range(40):
        val, (gs, gn) = grad(log_sigma, log_noise)
        log_sigma = log_sigma - lr * jnp.clip(gs / n, -0.5, 0.5) * n / n
        log_noise = log_noise - lr * jnp.clip(gn / n, -0.5, 0.5) * n / n
        if step % 8 == 0:
            print(f"step {step:3d} nll/n={float(val)/n:.4f} "
                  f"sigma={float(jnp.exp(log_sigma)):.3f} "
                  f"noise={float(jnp.exp(log_noise)):.3f}")
    print(f"final: sigma={float(jnp.exp(log_sigma)):.3f} "
          f"noise={float(jnp.exp(log_noise)):.3f}  "
          f"(true {true_sigma}/{true_noise})")


if __name__ == "__main__":
    main()
