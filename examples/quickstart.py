"""Quickstart: hierarchically compositional kernel ridge regression.

    PYTHONPATH=src python examples/quickstart.py

Fits HCK-KRR on a synthetic regression task, compares against Nyström / RFF
/ independent / exact baselines at equal rank, and shows the GP view
(posterior variance + log marginal likelihood via the structured logdet).
"""
import jax
import jax.numpy as jnp

from repro.core import baselines, gp, krr
from repro.core.kernels_fn import BaseKernel


def main():
    key = jax.random.PRNGKey(0)
    n, d = 4096, 8
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.uniform(k1, (n, d))
    f = lambda x: jnp.sin(6 * x[:, 0]) * jnp.cos(4 * x[:, 1]) + x[:, 2] ** 2
    y = f(x) + 0.05 * jax.random.normal(k2, (n,))
    xt = jax.random.uniform(k3, (1024, d))
    yt = f(xt)

    ker = BaseKernel("gaussian", sigma=0.7)
    lam, rank = 1e-2, 64

    print(f"n={n} d={d} rank={rank}  (memory ~4nr = {4*n*rank*4/1e6:.1f} MB)")
    m = krr.fit(x, y, kernel=ker, lam=lam, rank=rank, key=jax.random.PRNGKey(7))
    print(f"HCK-KRR      rel err: {float(krr.relative_error(m.predict(xt), yt)):.4f}")

    ny = baselines.fit_nystrom(x, y, kernel=ker, lam=lam, rank=rank,
                               key=jax.random.PRNGKey(8))
    print(f"Nystrom      rel err: {float(krr.relative_error(ny.predict(xt)[:, 0], yt)):.4f}")
    rf = baselines.fit_rff(x, y, kernel=ker, lam=lam, rank=rank,
                           key=jax.random.PRNGKey(9))
    print(f"RFF          rel err: {float(krr.relative_error(rf.predict(xt)[:, 0], yt)):.4f}")
    ind = baselines.fit_independent(x, y, kernel=ker, lam=lam, levels=6,
                                    key=jax.random.PRNGKey(10))
    print(f"independent  rel err: {float(krr.relative_error(ind.predict(xt), yt)):.4f}")
    ex = baselines.fit_exact(x, y, kernel=ker, lam=lam)
    print(f"exact (n^3)  rel err: {float(krr.relative_error(ex(xt), yt)):.4f}")

    # GP view: posterior mean/var + marginal likelihood at O(nr^2)
    g = gp.fit_gp(x[:1024], y[:1024], kernel=ker, noise=lam, rank=64,
                  levels=3, key=jax.random.PRNGKey(11))
    var = g.posterior_var(xt[:4])
    y_sorted = y[:1024][g.factors.tree.perm]
    print(f"GP posterior var (4 queries): {[round(float(v), 4) for v in var]}")
    print(f"GP log marginal likelihood:   {float(g.log_marginal_likelihood(y_sorted)):.1f}")


if __name__ == "__main__":
    main()
