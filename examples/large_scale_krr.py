"""End-to-end driver for the paper's own workload: large-scale HCK kernel
ridge classification (the SUSY/covtype regime of Table 1, synthetic
stand-in).

    PYTHONPATH=src python examples/large_scale_krr.py            # n=65536
    PYTHONPATH=src python examples/large_scale_krr.py --n 1048576  # paper scale

Exercises the full O(n r^2) pipeline: random-projection partitioning ->
factor instantiation -> Algorithm-2 inversion -> Algorithm-3 batched
prediction, and reports wall-times per stage (cf. paper §5.3 timing plots).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.hck_krr import HCKConfig
from repro.core import krr
from repro.core.kernels_fn import BaseKernel
from repro.data.pipeline import regression_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--d", type=int, default=18)
    ap.add_argument("--rank", type=int, default=128)
    ap.add_argument("--lam", type=float, default=1e-2)
    ap.add_argument("--sigma", type=float, default=1.0)
    args = ap.parse_args()

    cfg = HCKConfig("susy-like", n_train=args.n, n_test=args.n // 8, d=args.d,
                    task="binary")
    (x, y), (xt, yt) = regression_dataset(cfg, jax.random.PRNGKey(0))
    ker = BaseKernel("gaussian", sigma=args.sigma)

    t0 = time.perf_counter()
    model = krr.fit(x, y, kernel=ker, lam=args.lam, rank=args.rank,
                    key=jax.random.PRNGKey(1), classification=True)
    jax.block_until_ready(model.alpha)
    t_fit = time.perf_counter() - t0

    t0 = time.perf_counter()
    pred = model.predict_class(xt)
    jax.block_until_ready(pred)
    t_pred = time.perf_counter() - t0

    acc = float(krr.accuracy(pred, yt))
    n, r = args.n, args.rank
    print(f"n={n} d={args.d} r={r}  levels={model.factors.levels}")
    print(f"train (O(nr^2) = {n*r*r/1e9:.1f} Gflop-units): {t_fit:.2f}s")
    print(f"predict {len(yt)} pts (O(r^2 log) each):       {t_pred:.2f}s "
          f"({t_pred/len(yt)*1e6:.1f} us/query)")
    print(f"test accuracy: {acc:.4f}")
    print(f"memory (factors ~4nr floats): {4*n*r*4/1e9:.2f} GB")


if __name__ == "__main__":
    main()
