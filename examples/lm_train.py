"""End-to-end LM training driver: train a ~100M-parameter granite-family
model for a few hundred steps on the synthetic token pipeline, with
checkpoint/restart and (optional) int8 gradient compression.

    PYTHONPATH=src python examples/lm_train.py                 # CPU-sized
    PYTHONPATH=src python examples/lm_train.py --full          # ~100M params

The same step function is what the multi-pod dry-run lowers at the
deepseek-67b scale; here it executes for real on the local device and the
loss visibly drops on the structured synthetic stream.
"""
import argparse
import dataclasses
import tempfile

from repro.configs import TrainConfig, get_arch
from repro.data.pipeline import TokenPipeline
from repro.training.checkpoint import CheckpointManager
from repro.training.train_loop import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 200 steps (slow on 1 CPU core)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--compression", action="store_true")
    args = ap.parse_args()

    if args.full:
        # granite family at ~100M: 12L x 768d x 12H, ff 2048, vocab 16k
        cfg = dataclasses.replace(
            get_arch("granite-3-2b"), n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_head=64, d_ff=2048, vocab=16384, dtype="float32")
        steps = args.steps or 200
        seq, batch = 256, 8
    else:
        cfg = get_arch("granite-3-2b").reduced()
        steps = args.steps or 60
        seq, batch = 64, 8

    print(f"params ≈ {cfg.param_count()/1e6:.1f}M, steps={steps}")
    tcfg = TrainConfig(
        lr=3e-4 if args.full else 3e-3, warmup_steps=max(steps // 10, 1),
        total_steps=steps, checkpoint_every=max(steps // 4, 10),
        grad_compression="int8" if args.compression else "none")
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    with tempfile.TemporaryDirectory() as ckdir:
        _, _, hist = train_loop(cfg, tcfg, pipe, steps=steps,
                                manager=CheckpointManager(ckdir),
                                log_every=max(steps // 10, 1))
    first, last = hist[0][1]["loss"], hist[-1][1]["loss"]
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
