"""Long-context serving with the paper's technique: hierarchical (HCK)
attention decode vs exact decode — the long_500k story at CPU scale.

    PYTHONPATH=src python examples/long_context_serve.py

Builds a prefix KV cache, then compares per-token decode attention cost:
exact O(S) attention vs the Algorithm-3 hierarchical state (O(n0 + r)),
and reports agreement between the two on the same cache.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.attention_backends import (HCKAttnConfig,
                                             build_hck_decode_state,
                                             decode_attention,
                                             hck_attention,
                                             hck_decode_attention)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=16384)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--rank", type=int, default=64)
    ap.add_argument("--leaf", type=int, default=512)
    args = ap.parse_args()

    B, H, S, D = 1, args.heads, args.seq, args.dim
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, 1, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    cfg = HCKAttnConfig(leaf=args.leaf, rank=args.rank, levels=5).for_seq(S)
    n0 = S // (1 << cfg.levels)

    # one-off: collapse the prefix into the Algorithm-3 state
    t0 = time.perf_counter()
    state = jax.block_until_ready(build_hck_decode_state(k, v, cfg=cfg))
    t_build = time.perf_counter() - t0

    exact = jax.jit(lambda q, k, v: decode_attention(q, k, v, length=S))
    hck = jax.jit(hck_decode_attention)
    jax.block_until_ready(exact(q, k, v))
    jax.block_until_ready(hck(q, state))

    reps = 20
    t0 = time.perf_counter()
    for _ in range(reps):
        out_e = exact(q, k, v)
    jax.block_until_ready(out_e)
    t_exact = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    for _ in range(reps):
        out_h = hck(q, state)
    jax.block_until_ready(out_h)
    t_hck = (time.perf_counter() - t0) / reps

    # agreement vs the hierarchical TRAIN-path last row (same approximation)
    full_q = jax.random.normal(ks[0], (B, H, S, D)).at[:, :, -1:].set(q)
    ref = hck_attention(full_q, k, v, cfg=cfg)[:, :, -1:]
    agree = float(jnp.max(jnp.abs(out_h - ref)))

    print(f"cache S={S}, leaf n0={n0}, rank r={cfg.rank}, levels={cfg.levels}")
    print(f"state build (amortized over {n0} tokens): {t_build*1e3:.1f} ms "
          f"-> {t_build/n0*1e6:.1f} us/token")
    print(f"exact decode attention:        {t_exact*1e6:8.1f} us/token (O(S))")
    print(f"hierarchical decode attention: {t_hck*1e6:8.1f} us/token "
          f"(O(n0+r) = {n0 + cfg.rank} vs S = {S})")
    print(f"speedup: {t_exact/t_hck:.1f}x; agreement with train-path: {agree:.2e}")


if __name__ == "__main__":
    main()
